"""Shared toy-scale training harness for the paper-figure benchmarks.

All benchmarks train the paper's Gemma3-style arch at toy size (2 layers,
d=48) on the synthetic Markov LM so the suite finishes on a single CPU core
while preserving the *qualitative* orderings the paper reports (MuLoCo vs
DiLoCo, compression losslessness, streaming parity, worker-scaling slopes).
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.core import DiLoCoConfig, diloco_init, dp_config, make_optimizer
from repro.core.diloco import compute_deltas, inner_step
from repro.data import DataConfig, MarkovStream, batches_for_round
from repro.engine import TrainEngine
from repro.models import ModelConfig, build_model
from repro.optim import OptimizerConfig

TOY = ModelConfig(
    name="toy-paper", arch_type="dense", n_layers=2, d_model=48, n_heads=4,
    n_kv_heads=2, d_ff=96, vocab=128, activation="swiglu", qk_norm=True,
    post_norm=True, remat=False, dtype="float32",
)
SEQ = 32
BPW = 4  # batch per worker
ROUNDS = 5  # toy-scale orderings stabilize by round 5; keeps the suite CPU-friendly
LR = {"muon": 2e-2, "adamw": 4e-3}


def make_stream(n_workers: int, seed: int = 1, bpw: int = BPW) -> MarkovStream:
    return MarkovStream(DataConfig(vocab=TOY.vocab, seq_len=SEQ, batch_per_worker=bpw,
                                   n_workers=n_workers, seed=seed))


def eval_loss(model, params, seed: int = 991) -> float:
    stream = MarkovStream(DataConfig(vocab=TOY.vocab, seq_len=SEQ, batch_per_worker=16,
                                     n_workers=1, seed=seed))
    b = jax.tree.map(lambda x: x[0], stream.batch(0))
    return float(model.loss(params, b)[0])


def train_diloco(dcfg: DiLoCoConfig, rounds: int = ROUNDS, seed: int = 0,
                 bpw: int = BPW, lr: float | None = None) -> tuple[float, dict]:
    """Train through the unified engine: one donated, jitted round fn."""
    model = build_model(TOY)
    icfg = OptimizerConfig(lr=lr or LR[dcfg.inner_name], weight_decay=1e-4,
                           schedule="cosine", total_steps=rounds * dcfg.sync_interval)
    engine = TrainEngine(model, dcfg, icfg)
    state = engine.init(jax.random.PRNGKey(seed))
    stream = make_stream(dcfg.n_workers, bpw=bpw)
    t0 = time.time()
    for r in range(rounds):
        state, info = engine.step(state, batches_for_round(stream, r, dcfg.sync_interval))
    jax.block_until_ready(state["outer_params"])
    wall = time.time() - t0
    final = eval_loss(model, state["outer_params"])
    return final, {"wall_s": wall, "state": state, "model": model, "engine": engine}


def dp_baseline(inner: str, rounds: int = ROUNDS, H: int = 4, total_batch: int = BPW * 4,
                seed: int = 0) -> float:
    """FLOP-matched DP baseline: the degenerate (K=1, H=1, no-outer) engine."""
    final, _ = train_diloco(dp_config(inner), rounds=rounds * H, bpw=total_batch,
                            seed=seed)
    return final


def collect_pseudogradients(inner: str, K: int, H: int = 8, seed: int = 0,
                            warmup_rounds: int = 4, track_steps: bool = False):
    """Paper Fig. 2/4/5 methodology: train a DP checkpoint, *resume* it with
    K workers (optimizer state included) for H steps, and return the stacked
    worker deltas plus the FLOP-matched K=1 pseudogradient.

    ``track_steps`` additionally returns per-inner-step hidden-weight deltas
    [K, H, ...] for the step-norm analysis (Fig. 5).
    """
    model = build_model(TOY)
    icfg = OptimizerConfig(lr=LR[inner], weight_decay=1e-4)

    # --- warm up a single-worker checkpoint (mid-training regime) ---
    warm_cfg = DiLoCoConfig(n_workers=1, sync_interval=1, inner_name=inner,
                            outer_lr=1.0, outer_momentum=0.0)
    opt = make_optimizer(warm_cfg, icfg)
    wstate = diloco_init(model, warm_cfg, icfg, jax.random.PRNGKey(seed))
    wstream = make_stream(1, seed=11, bpw=BPW * K)
    step = jax.jit(functools.partial(inner_step, model, opt))
    for t in range(warmup_rounds * H):
        wstate, _ = step(wstate, wstream.batch(t))
    ckpt_params = jax.tree.map(lambda x: x[0], wstate["worker_params"])
    ckpt_opt = jax.tree.map(lambda x: x[0], wstate["inner_state"])

    def branch(n_workers: int, bpw: int, stream_seed: int):
        dcfg = DiLoCoConfig(n_workers=n_workers, sync_interval=H, inner_name=inner)
        state = diloco_init(model, dcfg, icfg, jax.random.PRNGKey(seed))
        state["outer_params"] = ckpt_params
        state["worker_params"] = jax.tree.map(
            lambda p: jnp.broadcast_to(p[None], (n_workers, *p.shape)), ckpt_params)
        state["inner_state"] = jax.tree.map(
            lambda s: jnp.broadcast_to(s[None], (n_workers, *s.shape)), ckpt_opt)
        stream = make_stream(n_workers, seed=stream_seed, bpw=bpw)
        per_step = []
        sfn = jax.jit(functools.partial(inner_step, model, opt))
        for h in range(H):
            prev = state["worker_params"]["layers"]
            state, _ = sfn(state, stream.batch(h))
            if track_steps:
                per_step.append(jax.tree.map(
                    lambda a, b: (a - b).astype(jnp.float32),
                    state["worker_params"]["layers"], prev))
        steps = (jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *per_step)
                 if track_steps else None)
        return state, steps

    state_k, steps_k = branch(K, BPW, stream_seed=5)
    deltas_k = compute_deltas(state_k)
    psi_k = jax.tree.map(lambda d: jnp.mean(d, axis=0), deltas_k)

    state_1, _ = branch(1, BPW * K, stream_seed=5)
    psi_1 = jax.tree.map(lambda d: d[0], compute_deltas(state_1))
    if track_steps:
        return deltas_k, psi_k, psi_1, steps_k
    return deltas_k, psi_k, psi_1
