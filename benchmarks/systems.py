"""System-level benchmarks: measured train-path throughput (engine vs
per-step dispatch), wallclock/bandwidth model (Tab. 9/10, Fig. 16),
scaling-law fitting (Tab. 2), kernel microbenchmarks, roofline table."""
from __future__ import annotations

import functools
import glob
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import CompressionConfig
from repro.core.scaling_laws import fit_power_law
from repro.core.wallclock import RunSpec, compute_utilization, training_time_hours


def bench_train_throughput(rounds: int = 4, warmup: int = 1,
                           reps: int = 2) -> list[dict]:
    """Measured steps/s on the reduced smollm-135m config, plus an R-sweep:

      * ``per_step``  — jit(inner_step) x H + jit(outer_step), host loop with
        a blocking loss read per step (fully unfused dispatch — how the
        pre-engine analysis/dry-run paths drove training);
      * ``seed_path`` — undonated jit(diloco_round) with a blocking metrics
        read every round (what launch/train.py did pre-engine);
      * ``engine``    — the unified TrainEngine at R=1: donated fused round +
        async metrics drain via the driver (one dispatch per round);
      * ``superstep_rN`` — the same engine dispatching N rounds per superstep
        (scan-over-R), which amortizes the per-round host dispatch away.

    The shape is dispatch-sensitive (small per-step compute, long H) so the
    executor — not the matmuls — determines steps/s. Variants are measured
    ``reps`` times interleaved and the best rep is reported, which rejects
    the load spikes of a shared box.
    """
    from repro.configs import get_config, reduce_config
    from repro.core import DiLoCoConfig, diloco_round, inner_step, make_optimizer, outer_step
    from repro.data import DataConfig, MarkovStream, batches_for_round, batches_for_span
    from repro.engine import TrainEngine, run_rounds
    from repro.models import build_model
    from repro.optim import OptimizerConfig

    cfg = reduce_config(get_config("smollm-135m"))
    model = build_model(cfg)
    K, H, SEQ, BPW_ = 4, 16, 16, 1
    dcfg = DiLoCoConfig(n_workers=K, sync_interval=H, inner_name="muon")
    icfg = OptimizerConfig(lr=2e-2, weight_decay=1e-4, schedule="constant")
    stream = MarkovStream(DataConfig(vocab=cfg.vocab, seq_len=SEQ,
                                     batch_per_worker=BPW_, n_workers=K, seed=1))
    total = rounds + warmup
    round_batches = [batches_for_round(stream, r, H) for r in range(total)]
    step_batches = [stream.batch(t) for t in range(total * H)]
    # pre-generated span batches for the R-sweep (data gen stays out of the
    # timed region, as it does for the other variants)
    R_SWEEP = tuple(r for r in (2, 4) if rounds % r == 0)
    span_batches = {
        (r0, n): batches_for_span(stream, r0, H, n)
        for n in R_SWEEP for r0 in range(warmup, total, n)
    }
    opt = make_optimizer(dcfg, icfg)

    def bench_per_step() -> float:
        state = TrainEngine(model, dcfg, icfg).init(jax.random.PRNGKey(0))
        step_fn = jax.jit(functools.partial(inner_step, model, opt))
        sync_fn = jax.jit(functools.partial(outer_step, dcfg))

        def run(state, lo, hi):
            for r in range(lo, hi):
                for h in range(H):
                    state, m = step_fn(state, step_batches[r * H + h])
                    float(m["loss"])  # blocking per-step metric read
                state, _ = sync_fn(state)
            return state

        state = run(state, 0, warmup)
        t0 = time.perf_counter()
        run(state, warmup, total)
        return rounds * H / (time.perf_counter() - t0)

    def bench_seed_path() -> float:
        state = TrainEngine(model, dcfg, icfg).init(jax.random.PRNGKey(0))
        fn = jax.jit(functools.partial(diloco_round, model, dcfg, opt, masks=None))
        for r in range(warmup):
            state, info = fn(state, round_batches[r])
            float(info["loss"].mean())
        t0 = time.perf_counter()
        for r in range(warmup, total):
            state, info = fn(state, round_batches[r])
            float(info["loss"].mean())
        return rounds * H / (time.perf_counter() - t0)

    def bench_engine() -> float:
        engine = TrainEngine(model, dcfg, icfg)
        state = engine.init(jax.random.PRNGKey(0))
        state, _ = run_rounds(engine, state, lambda r: round_batches[r], warmup)
        t0 = time.perf_counter()
        state, _ = run_rounds(engine, state, lambda r: round_batches[r], total,
                              start=warmup)
        jax.block_until_ready(state["outer_params"])
        return rounds * H / (time.perf_counter() - t0)

    def bench_superstep(R: int):
        def run() -> float:
            engine = TrainEngine(model, dcfg, icfg)
            state = engine.init(jax.random.PRNGKey(0))
            state, _ = run_rounds(engine, state, lambda r: round_batches[r], warmup)
            # compile + execute the R-wide dispatch outside the timed region
            state, _ = engine.superstep(state, span_batches[(warmup, R)])
            jax.block_until_ready(state["outer_params"])
            t0 = time.perf_counter()
            state, _ = run_rounds(engine, state, lambda r: round_batches[r],
                                  total, start=warmup, rounds_per_dispatch=R,
                                  span_batches_for=lambda r0, n: span_batches[(r0, n)])
            jax.block_until_ready(state["outer_params"])
            return rounds * H / (time.perf_counter() - t0)

        return run

    single_dispatch_telemetry: dict = {}

    def bench_single_dispatch() -> float:
        # whole-span dispatch via the cost model ("auto" unmeasured = one
        # program for the run); telemetry pins the dispatch count the row's
        # derived field reports
        engine = TrainEngine(model, dcfg, icfg)
        state = engine.init(jax.random.PRNGKey(0))
        state, _ = run_rounds(engine, state, lambda r: round_batches[r], warmup)
        span = {(warmup, rounds): batches_for_span(stream, warmup, H, rounds)}
        state, _ = engine.superstep(state, span[(warmup, rounds)])
        jax.block_until_ready(state["outer_params"])
        t0 = time.perf_counter()
        state, _ = run_rounds(engine, state, lambda r: round_batches[r],
                              total, start=warmup, rounds_per_dispatch="auto",
                              span_batches_for=lambda r0, n: span[(r0, n)],
                              telemetry=single_dispatch_telemetry)
        jax.block_until_ready(state["outer_params"])
        return rounds * H / (time.perf_counter() - t0)

    variants = {"per_step": bench_per_step, "seed_path": bench_seed_path,
                "engine": bench_engine}
    variants.update({f"superstep_r{R}": bench_superstep(R) for R in R_SWEEP})
    variants["single_dispatch"] = bench_single_dispatch
    best = {name: 0.0 for name in variants}
    for _ in range(reps):
        for name, fn in variants.items():
            best[name] = max(best[name], fn())

    rows = [
        {"name": "train_throughput/per_step", "value": round(best["per_step"], 3),
         "derived": "steps_per_s"},
        {"name": "train_throughput/seed_path", "value": round(best["seed_path"], 3),
         "derived": "steps_per_s"},
        {"name": "train_throughput/engine", "value": round(best["engine"], 3),
         "derived": f"steps_per_s;"
                    f"speedup_vs_seed={best['engine'] / best['seed_path']:.2f}x;"
                    f"speedup_vs_per_step={best['engine'] / best['per_step']:.2f}x"},
    ]
    for R in R_SWEEP:
        v = best[f"superstep_r{R}"]
        rows.append({
            "name": f"train_throughput/superstep_r{R}", "value": round(v, 3),
            "derived": f"steps_per_s;rounds_per_dispatch={R};"
                       f"speedup_vs_r1_engine={v / best['engine']:.2f}x",
        })
    v = best["single_dispatch"]
    rows.append({
        "name": "train_throughput/single_dispatch", "value": round(v, 3),
        "derived": f"steps_per_s;"
                   f"dispatches={single_dispatch_telemetry.get('dispatches')};"
                   f"speedup_vs_r1_engine={v / best['engine']:.2f}x",
    })
    return rows


def bench_optimizer_sweep(rounds: int = 3, warmup: int = 1) -> list[dict]:
    """Inner-optimizer sweep at the throughput-bench shape (K=4, H=16,
    seq=16, bpw=1): measured engine steps/s per transform-chain optimizer.

    ``muon_bp`` runs at ns_period=H (one orthogonalization per round — the
    round boundary aligns with the period). On CPU the vmapped lax.cond
    lowers to select, so the NS saving shows up on accelerators; here the
    row mainly proves the variant lowers through the same donated round.
    """
    from repro.configs import get_config, reduce_config
    from repro.core import DiLoCoConfig
    from repro.data import DataConfig, MarkovStream, batches_for_round
    from repro.engine import TrainEngine, run_rounds
    from repro.models import build_model
    from repro.optim import OptimizerConfig

    cfg = reduce_config(get_config("smollm-135m"))
    model = build_model(cfg)
    K, H, SEQ, BPW_ = 4, 16, 16, 1
    stream = MarkovStream(DataConfig(vocab=cfg.vocab, seq_len=SEQ,
                                     batch_per_worker=BPW_, n_workers=K, seed=1))
    total = rounds + warmup
    round_batches = [batches_for_round(stream, r, H) for r in range(total)]

    rows = []
    for inner in ("adamw", "muon", "muon_bp"):
        icfg = OptimizerConfig(lr=2e-2, weight_decay=1e-4, schedule="constant",
                               ns_period=H if inner == "muon_bp" else 1)
        dcfg = DiLoCoConfig(n_workers=K, sync_interval=H, inner_name=inner)
        engine = TrainEngine(model, dcfg, icfg)
        state = engine.init(jax.random.PRNGKey(0))
        state, _ = run_rounds(engine, state, lambda r: round_batches[r], warmup)
        t0 = time.perf_counter()
        state, _ = run_rounds(engine, state, lambda r: round_batches[r], total,
                              start=warmup)
        jax.block_until_ready(state["outer_params"])
        sps = rounds * H / (time.perf_counter() - t0)
        rows.append({"name": f"optimizer_bench/{inner}",
                     "value": round(sps, 3), "derived": "steps_per_s"})
    return rows


def bench_compression_sweep(rounds: int = 3) -> list[dict]:
    """compression_bench: loss + *measured* wire bytes across bits/topk_frac.

    Each config trains the toy model through the engine's wire-format
    collective path (real codes + metadata + indices on the simulated wire)
    and reports the final eval loss alongside three byte accountings per
    sync per worker: measured (actual wire-buffer shapes/dtypes, the number
    the engine's per-round ``comm_bytes`` metric carries), the closed-form
    model (``collective_bytes_tree``), and the measured/dense ratio. The
    measured-vs-modeled gap is the metadata + packing overhead the ratio
    model ignores (see docs/benchmarks.md).
    """
    from benchmarks.common import TOY, train_diloco
    from repro.core import DiLoCoConfig
    from repro.core.collectives import (
        collective_bytes_tree,
        measured_compression_ratio,
        measured_sync_bytes,
    )
    from repro.models import build_model

    K, H = 2, 4
    params_abs = jax.eval_shape(
        lambda: build_model(TOY).init(jax.random.PRNGKey(0)))
    configs = [("none", CompressionConfig(kind="none"))]
    for bits in (8, 4, 2):
        configs.append((f"quant{bits}_rw_ef", CompressionConfig(
            kind="quant", bits=bits, rowwise=True, error_feedback=True)))
    configs.append(("quant4_global_ef", CompressionConfig(
        kind="quant", bits=4, error_feedback=True)))
    for frac in (0.01, 0.1):
        configs.append((f"topk{frac}_ef", CompressionConfig(
            kind="topk", topk_frac=frac, error_feedback=True,
            collective="gather")))

    rows = []
    for name, comp in configs:
        dcfg = DiLoCoConfig(n_workers=K, sync_interval=H, inner_name="muon",
                            compression=comp)
        loss, extra = train_diloco(dcfg, rounds=rounds)
        measured = measured_sync_bytes(params_abs, comp, K)
        modeled = collective_bytes_tree(params_abs, comp, K)[
            "bytes_per_sync_per_worker"]
        ratio = measured_compression_ratio(params_abs, comp, K)
        rows.append({
            "name": f"compression_bench/{name}", "value": round(loss, 4),
            "derived": (f"loss;measured_B={measured};modeled_B={modeled};"
                        f"measured_ratio={ratio:.4f};"
                        f"wall_s={extra['wall_s']:.1f}"),
        })
    return rows


def bench_serve_throughput(reps: int = 2) -> list[dict]:
    """serve_bench: useful decode tokens/s on a heterogeneous request mix,
    serving engines vs the seed loop (reduced smollm-135m, greedy).

    The workload is the one serving engines exist for: more requests than
    batch slots, prompt lengths varying 4..32 and per-request ``max_new``
    varying 4..48. Three servers per (slots, workload) shape:

      * ``per_token``  — the seed loop as a server (static batching): FIFO
        waves of ``slots`` requests, every prompt right-padded to the wave
        max (the dense path has no padding mask), prefill by stepping the
        decode path token by token, one host dispatch per generated token,
        and the whole wave held until its longest ``max_new`` finishes;
      * ``naive``      — same static waves, but the prompt prefilled in
        ONE batched dispatch (still per-token decode);
      * ``paged_ps{N}`` — the paged continuous-batching engine at page
        size N: requests admitted into freed slots mid-flight, decode
        spans of 8 tokens per donated jitted ``lax.scan`` dispatch.

    Throughput counts *useful* tokens only (sum of requested ``max_new``):
    tokens a static wave decodes for already-finished or padded slots are
    wasted work, which is precisely the waste continuous batching removes.
    Variants are measured ``reps`` times, best rep reported, one untimed
    warmup run each so compile stays out of the numbers. ``derived``
    carries each variant's speedup over the seed loop; the paged engine is
    required to clear 3x.
    """
    from repro.configs import get_config, reduce_config
    from repro.models import build_model
    from repro.serving import PagedEngine, Request, naive_generate, pages_needed

    cfg = reduce_config(get_config("smollm-135m"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    SPAN = 8
    P_MIX = (4, 32, 8, 16)
    N_MIX = (4, 48, 8, 16)

    def workload(n_req: int) -> list[Request]:
        reqs = []
        for i in range(n_req):
            plen, nnew = P_MIX[i % len(P_MIX)], N_MIX[i % len(N_MIX)]
            toks = np.asarray(jax.random.randint(
                jax.random.PRNGKey(100 + i), (plen,), 0, cfg.vocab))
            reqs.append(Request(f"r{i}", tuple(int(t) for t in toks), nnew))
        return reqs

    rows = []
    for slots, n_req in ((2, 6), (4, 12)):
        reqs = workload(n_req)
        useful = sum(r.max_new for r in reqs)

        def t_static(batched_prefill):
            def run() -> float:
                t0 = time.perf_counter()
                for w0 in range(0, len(reqs), slots):
                    wave = reqs[w0: w0 + slots]
                    pmax = max(len(r.tokens) for r in wave)
                    prompts = np.zeros((len(wave), pmax), np.int32)
                    for i, r in enumerate(wave):
                        prompts[i, : len(r.tokens)] = r.tokens
                    out = naive_generate(model, params, jnp.asarray(prompts),
                                         max(r.max_new for r in wave),
                                         batched_prefill=batched_prefill)
                    np.asarray(out)
                return useful / (time.perf_counter() - t0)

            return run

        def t_paged(ps):
            budget = max(pages_needed(len(r.tokens) + r.max_new + SPAN, ps)
                         for r in reqs)
            engine = PagedEngine(model, params, slots=slots, page_size=ps,
                                 max_pages=1 + slots * budget,
                                 decode_steps_per_dispatch=SPAN)

            def run() -> float:
                t0 = time.perf_counter()
                engine.run(reqs)
                return useful / (time.perf_counter() - t0)

            return run

        variants = {"per_token": t_static(False), "naive": t_static(True),
                    "paged_ps8": t_paged(8), "paged_ps16": t_paged(16)}
        best = {}
        for name, fn in variants.items():
            fn()  # warmup: compile outside the timed reps
            best[name] = max(fn() for _ in range(reps))
        for name, tps in best.items():
            rows.append({
                "name": f"serve_bench/slots{slots}_req{n_req}/{name}",
                "value": round(tps, 1),
                "derived": f"useful_tok_per_s;speedup_vs_per_token="
                           f"{tps / best['per_token']:.2f}x",
            })
        # acceptance: paged continuous batching >= 3x the seed loop
        assert max(best["paged_ps8"], best["paged_ps16"]) >= 3 * best["per_token"], best
    return rows


def bench_fault_bench(rounds: int = 5) -> list[dict]:
    """fault_bench: elastic-DiLoCo degradation curves on the toy model.

    Two curve families per worker count K in {2, 4}, both through the real
    engine (donated fused round, participation mask / pending FIFO in the
    program — not a host-side simulation):

      * ``staleness``  — final eval loss vs ``sync_delay`` d in {0, 1, 2}
        (delayed outer sync, full participation): how much convergence the
        overlap window costs when the pseudogradient lands d rounds late;
      * ``drop``       — final eval loss vs i.i.d. per-round drop
        probability p in {0, 0.25, 0.5} (lockstep sync): how much worker
        churn costs when dropped workers freeze and the reduce averages the
        survivors. ``derived`` carries the realized mean active-worker
        count and the mean per-round wire fraction, which the elastic
        comm_bytes metric scales by construction.

    The d=0 / p=0 anchors of the two families are the same dense run, so
    the curves share a baseline by construction.
    """
    from benchmarks.common import LR, TOY, eval_loss, make_stream
    from repro.core import DiLoCoConfig
    from repro.core.faults import FaultPlan
    from repro.data import batches_for_round
    from repro.engine import TrainEngine, run_rounds
    from repro.models import build_model
    from repro.optim import OptimizerConfig

    H = 4

    def run(K: int, sync_delay: int = 0, drop_prob: float = 0.0):
        dcfg = DiLoCoConfig(n_workers=K, sync_interval=H, inner_name="muon",
                            elastic=drop_prob > 0, sync_delay=sync_delay)
        model = build_model(TOY)
        icfg = OptimizerConfig(lr=LR["muon"], weight_decay=1e-4,
                               schedule="cosine", total_steps=rounds * H)
        engine = TrainEngine(model, dcfg, icfg)
        state = engine.init(jax.random.PRNGKey(0))
        stream = make_stream(K)
        plan = FaultPlan(n_workers=K, drop_prob=drop_prob, seed=7)
        state, hist = run_rounds(
            engine, state,
            lambda r: batches_for_round(stream, r, H), rounds,
            participation_for=plan.masks if drop_prob > 0 else None)
        active = [h.get("active_workers", float(K)) for h in hist]
        return (eval_loss(model, state["outer_params"]),
                float(np.mean(active)) if active else float(K))

    rows = []
    for K in (2, 4):
        for d in (0, 1, 2):
            loss, _ = run(K, sync_delay=d)
            rows.append({"name": f"fault_bench/staleness/K{K}/d{d}",
                         "value": round(loss, 4),
                         "derived": f"loss;sync_delay={d}"})
        for p in (0.0, 0.25, 0.5):
            loss, mean_active = run(K, drop_prob=p)
            rows.append({"name": f"fault_bench/drop/K{K}/p{p}",
                         "value": round(loss, 4),
                         "derived": (f"loss;drop_prob={p};"
                                     f"mean_active={mean_active:.2f};"
                                     f"wire_frac={mean_active / K:.3f}")})
    return rows


def bench_tab10_wallclock() -> list[dict]:
    """Tab. 10: idealized 15B training hours across bandwidths."""
    rows = []
    n = 15.23e9
    base = dict(n_params=n, n_active_params=n, seq_len=2048, n_steps=145_000)
    specs = {
        "dp_adamw_bs2M": RunSpec(**base, batch_tokens=2.1e6, sync_interval=1,
                                 optimizer_overhead=0.0),
        "dp_muon_bs4M": RunSpec(**base, batch_tokens=4.2e6, sync_interval=1),
        "diloco_k1_bs1M": RunSpec(**base, batch_tokens=1e6, sync_interval=30,
                                  optimizer_overhead=0.0),
        "muloco_k1_bs16M": RunSpec(**base, batch_tokens=16.8e6, sync_interval=30),
        "diloco_k16_bs4M": RunSpec(**base, batch_tokens=4.2e6, sync_interval=30,
                                   n_workers=16, optimizer_overhead=0.0),
        "muloco_k16_bs8M": RunSpec(**base, batch_tokens=8.4e6, sync_interval=30,
                                   n_workers=16),
    }
    # steps scale inversely with batch (fixed token budget 304.6B)
    for name, s in specs.items():
        steps = 304.6e9 / s.batch_tokens
        s = RunSpec(**{**s.__dict__, "n_steps": steps})
        for bw in (10e9, 100e9, 1600e9, 12800e9):
            rows.append({
                "name": f"tab10/{name}/bw={bw / 1e9:.0f}Gbit",
                "value": round(training_time_hours(s, bw), 2),
                "derived": "hours",
            })
    return rows


def bench_fig16_utilization() -> list[dict]:
    """Fig. 16: compute utilization vs bandwidth, per method/compression.

    The 4-bit entry uses the *measured* compression ratio (real wire
    buffers on a representative parameter tree — codes + row metadata +
    packing padding) instead of the bits/32 model; the gap between the two
    is documented in docs/benchmarks.md.
    """
    from repro.configs import get_config, reduce_config
    from repro.core.collectives import measured_compression_ratio
    from repro.models import build_model

    rows = []
    n = 3.07e9
    base = dict(n_params=n, n_active_params=n, seq_len=2048, n_steps=1,
                batch_tokens=2e6)
    cfg = reduce_config(get_config("smollm-135m"))
    params_abs = jax.eval_shape(
        lambda: build_model(cfg).init(jax.random.PRNGKey(0)))
    q4 = CompressionConfig(kind="quant", bits=4, rowwise=True)
    methods = {
        "dp": RunSpec(**base, sync_interval=1),
        "diloco_h30": RunSpec(**base, sync_interval=30),
        "diloco_h30_4bit": RunSpec(**base, sync_interval=30,
                                   compression_ratio=measured_compression_ratio(
                                       params_abs, q4, n_workers=1)),
    }
    for name, s in methods.items():
        for bw in (1e9, 10e9, 100e9, 1000e9):
            rows.append({
                "name": f"fig16/{name}/bw={bw / 1e9:.0f}Gbit",
                "value": round(compute_utilization(s, bw), 4),
                "derived": "utilization",
            })
    return rows


def bench_tab2_scaling_forms() -> list[dict]:
    """Tab. 2: residuals of L(C)=aC^a vs +irreducible on held-out scale."""
    rng = np.random.default_rng(0)
    C = np.logspace(18.5, 22.5, 6)
    true = 5.2e3 * C ** -0.197 + 1.711
    L = true * np.exp(rng.normal(0, 0.002, C.shape))
    train_C, train_L = C[:-1], L[:-1]
    rows = []
    for label, kw in (("simple", dict(irr=0.0)), ("irr", dict(fit_irr=True))):
        fit = fit_power_law(train_C, train_L, restarts=64, **kw)
        holdout = float(fit.residuals(C[-1:], L[-1:])[0])
        rows.append({
            "name": f"tab2/{label}",
            "value": round(holdout, 5),
            "derived": f"alpha={fit.alpha:.4f};irr={fit.irr:.3f}",
        })
    assert rows[1]["value"] <= rows[0]["value"]  # paper: +irr extrapolates better
    return rows


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_kernel_micro() -> list[dict]:
    """Pallas kernels (interpret mode) vs jnp reference — us/call."""
    from repro.kernels import ops, ref

    rows = []
    g = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
    ns_p = jax.jit(lambda x: ops.ns_orthogonalize(x))
    ns_r = jax.jit(lambda x: ref.ns_orthogonalize_ref(x))
    rows.append({"name": "kernel/ns_pallas_interpret", "value": round(_time(ns_p, g), 1),
                 "derived": "us_per_call"})
    rows.append({"name": "kernel/ns_jnp_ref", "value": round(_time(ns_r, g), 1),
                 "derived": "us_per_call"})
    x = jax.random.normal(jax.random.PRNGKey(1), (512, 1024), jnp.float32)
    q_p = jax.jit(lambda x: ops.quantize_rowwise(x, 4)[0])
    q_r = jax.jit(lambda x: ref.rowwise_quantize_ref(x, 4)[0])
    rows.append({"name": "kernel/quant_pallas_interpret", "value": round(_time(q_p, x), 1),
                 "derived": "us_per_call"})
    rows.append({"name": "kernel/quant_jnp_ref", "value": round(_time(q_r, x), 1),
                 "derived": "us_per_call"})
    return rows


def bench_attention_sweep() -> list[dict]:
    """attention_bench: seq x impl x window sweep of the attention backends.

    Times one full-sequence ``attend`` call (the per-layer training hot
    path) for the three execution paths — dense XLA softmax, blockwise XLA
    with schedule skipping, and the fused Pallas flash-attention kernel
    (interpret mode on CPU, so its absolute time measures the interpreter,
    not TPU perf — the row exists to track the schedule, not the clock).
    ``derived`` reports the visit schedule's fraction of the dense block
    grid and the achieved fraction of dense-attention FLOP throughput
    (``visited_fraction * t_dense / t``): > 1 means block skipping bought
    real wall-clock on top of what dense does.
    """
    from repro.kernels.flash_attention import visited_fraction
    from repro.models import ModelConfig
    from repro.models.attention import attend, init_attention

    B, H, KV, hd = 2, 4, 2, 16
    d = 64
    rows = []
    for S in (128, 256):
        for window in (0, S // 4):
            base = ModelConfig(n_layers=1, d_model=d, n_heads=H, n_kv_heads=KV,
                               head_dim=hd, d_ff=d, vocab=64, dtype="float32",
                               qk_norm=False, sliding_window=window,
                               attn_block_q=64, attn_block_kv=64)
            p = init_attention(jax.random.PRNGKey(0), base)
            x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32)
            pos = jnp.arange(S)
            impls = {
                "xla_dense": base.replace(blockwise_threshold=S + 1),
                "xla_blockwise": base.replace(blockwise_threshold=S),
                "pallas": base.replace(attn_impl="pallas"),
            }
            frac = visited_fraction(S, 64, 64, causal=True, window=window)
            t_dense = None
            for name, cfg in impls.items():
                fn = jax.jit(lambda x, cfg=cfg: attend(p, cfg, x, pos))
                us = _time(fn, x)
                if t_dense is None:
                    t_dense = us
                rows.append({
                    "name": f"attention_bench/S{S}_w{window}/{name}",
                    "value": round(us, 1),
                    "derived": (f"us_per_call;visited_frac={frac:.3f};"
                                f"frac_of_dense_flops="
                                f"{frac * t_dense / us:.3f}"),
                })
    return rows


_MESH_KERNEL_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.flash_attention import gqa_flash_attention
from repro.kernels.partition import kernel_partitioning
from repro.launch.mesh import make_debug_mesh
from repro.launch.sharding import kernel_specs

mesh = make_debug_mesh(data=2, model=2, pod=2)
parts = kernel_specs(mesh)


def timeit(fn, iters=5):
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
B, S, H, KV, hd = 4, 128, 4, 2, 32
q = jax.random.normal(k1, (B, S, H, hd), jnp.float32)
k = jax.random.normal(k2, (B, S, KV, hd), jnp.float32)
v = jax.random.normal(k3, (B, S, KV, hd), jnp.float32)
x = jax.random.normal(k1, (256, 512), jnp.float32)
g = jax.random.normal(k2, (4, 64, 48), jnp.float32)
t = jax.random.normal(k1, (256, 128), jnp.float32)
p = jax.random.normal(k2, (256, 128), jnp.float32)
u = jax.random.normal(k3, (256, 128), jnp.float32)
cases = {
    "flash": (
        lambda: gqa_flash_attention(q, k, v, causal=True, block_q=32, block_kv=64),
        lambda: ref.gqa_attention_ref(q, k, v, causal=True)),
    "quantize": (lambda: ops.quantize_rowwise(x, 4)[0],
                 lambda: ref.rowwise_quantize_ref(x, 4)[0]),
    "ns": (lambda: ops.ns_orthogonalize(g, block=16),
           lambda: ref.ns_orthogonalize_ref(g)),
    "outer_update": (
        lambda: ops.nesterov_update(t, p, u, lr=0.7, momentum=0.9),
        lambda: ref.nesterov_update_ref(t, p, u, lr=0.7, momentum=0.9)),
}
out = {"_partitioning": {
    "flash_axes": list(parts.flash_axes),
    "quantize_axes": list(parts.quantize_axes),
    "ns_axes": list(parts.ns_axes),
    "outer_tp": parts.outer_tp,
}}
for name, (pallas_fn, xla_fn) in cases.items():
    with kernel_partitioning(parts), mesh:
        t_sm = timeit(jax.jit(pallas_fn))
    with mesh:
        t_xla = timeit(jax.jit(xla_fn))
    out[name] = {"shard_map_us": t_sm, "xla_us": t_xla}
print(json.dumps(out))
"""


def bench_mesh_kernels() -> list[dict]:
    """mesh_kernel_bench: shard_mapped Pallas vs XLA on an 8-host-device mesh.

    Spawns a child with ``--xla_force_host_platform_device_count=8`` (XLA
    pins the device count at first init, so this process keeps its single
    device) and a (pod=2, data=2, model=2) mesh, then times each kernel
    two ways under the mesh: the shard_mapped Pallas path (kernel routing
    installed) and the GSPMD-partitioned jnp/XLA reference.

    CPU dispatch proxy: Pallas runs in interpret mode here, so absolute
    times measure interpreter + per-shard dispatch overhead, not TPU kernel
    perf — the rows exist to prove every kernel *executes* shard_mapped on
    a mesh and to track the dispatch-level cost of the routing; the
    speedup column only becomes a perf claim on real accelerators.
    """
    import os
    import subprocess
    import sys

    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", _MESH_KERNEL_CHILD],
                         capture_output=True, text=True, env=env, timeout=900)
    if res.returncode != 0:
        raise RuntimeError(f"mesh kernel child failed: {res.stderr[-2000:]}")
    data = json.loads(res.stdout.strip().splitlines()[-1])
    parts = data.pop("_partitioning", {})
    print(f"# mesh_kernel_bench partitioning: {parts}", file=sys.stderr,
          flush=True)
    rows = []
    for kernel, rec in data.items():
        speedup = rec["xla_us"] / max(rec["shard_map_us"], 1e-9)
        rows.append({
            "name": f"mesh_kernel_bench/{kernel}/shard_map",
            "value": round(rec["shard_map_us"], 1),
            "derived": (f"us_per_call;cpu_dispatch_proxy;"
                        f"speedup_vs_xla={speedup:.3f}"),
        })
        rows.append({
            "name": f"mesh_kernel_bench/{kernel}/xla",
            "value": round(rec["xla_us"], 1),
            "derived": "us_per_call;cpu_dispatch_proxy",
        })
    return rows


def bench_roofline_table(dryrun_dir: str = "results/dryrun") -> list[dict]:
    """The 40-combination baseline roofline table from the dry-run records."""
    rows = []
    for path in sorted(glob.glob(f"{dryrun_dir}/*.json")):
        for rec in json.load(open(path)):
            if rec["status"] != "ok":
                if rec["status"] == "skipped":
                    rows.append({"name": f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
                                 "value": "skip", "derived": rec["reason"]})
                continue
            r = rec["roofline"]
            rows.append({
                "name": f"roofline/{rec['arch']}/{rec['shape']}/{rec['plan']}/{rec['mesh']}",
                "value": f"{max(r['compute_s'], r['memory_s'], r['collective_s']):.3e}",
                "derived": (f"dom={r['dominant']};C={r['compute_s']:.2e};"
                            f"M={r['memory_s']:.2e};X={r['collective_s']:.2e};"
                            f"useful={r['useful_flops_ratio']:.2f};"
                            f"peakGiB={rec['memory']['peak_per_chip_gib']}"),
            })
    return rows
