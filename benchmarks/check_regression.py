"""Benchmark-regression gate: diff BENCH_<target>.json against the baseline.

    PYTHONPATH=src python -m benchmarks.check_regression --json results/bench

The CI tier runs the pinned :data:`REGRESSION_TARGETS` subset through
``benchmarks.run --json`` and hands the emitted ``BENCH_<target>.json``
artifacts to this checker, which compares every row against the committed
``benchmarks/baseline.json`` and exits 1 on any regression.

Comparison semantics: the direction of "worse" is read off each row's
``derived`` unit prefix — ``steps_per_s`` regresses when the value DROPS
below ``baseline * (1 - tol)``; ``us_per_call`` (and any other ``*_s`` /
``*_us`` timing unit) regresses when it RISES above ``baseline * (1 + tol)``.
Unitless rows are checked two-sided. The default tolerance band is wide
(50%) because the values are wall-clock on shared CI runners; the gate
exists to catch step-function regressions (a kernel dropping out of its
fused path, the superstep degrading to per-round dispatch), not percent
drift. Per-row overrides live in baseline.json's ``tolerance`` map.

Rows present in the run but absent from the baseline are reported as NEW
(not failures — a freshly added bench lands first, its baseline next);
baseline rows missing from the run FAIL, so a silently dying bench cannot
pass the gate. ``--update`` rewrites the baseline from the run instead of
checking (the maintainer path after an intentional perf change).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# The pinned CI subset: dispatch-architecture throughput, the optimizer
# sweep, and the kernel microbenches. Kept deliberately small — every
# target here runs on every gated CI invocation.
REGRESSION_TARGETS = ("train_throughput", "optimizer_bench", "kernels")

DEFAULT_TOLERANCE = 0.50
BASELINE_PATH = os.path.join(os.path.dirname(__file__), "baseline.json")

# derived-field unit prefix -> regression direction
_LOWER_IS_BETTER = ("us_per_call", "ms_per_call", "s_per_call", "seconds",
                    "us", "ms", "wall_s")
_HIGHER_IS_BETTER = ("steps_per_s", "tokens_per_s", "per_s", "gflops",
                     "speedup")


def direction(derived: str) -> int:
    """+1 = higher is better, -1 = lower is better, 0 = two-sided."""
    unit = (derived or "").split(";", 1)[0].strip()
    if unit in _HIGHER_IS_BETTER or unit.endswith("_per_s"):
        return 1
    if unit in _LOWER_IS_BETTER or unit.endswith(("_us", "_ms", "_s")):
        return -1
    return 0


def compare_rows(run_rows: dict, base_rows: dict, tolerance: dict) -> list[str]:
    """Return the list of failure strings for one target."""
    failures = []
    for name, base in base_rows.items():
        if name not in run_rows:
            failures.append(f"{name}: MISSING from run (baseline has it)")
            continue
        row = run_rows[name]
        try:
            val, ref = float(row["value"]), float(base["value"])
        except (TypeError, ValueError):
            if str(row["value"]) != str(base["value"]):
                failures.append(f"{name}: non-numeric value changed "
                                f"{base['value']!r} -> {row['value']!r}")
            continue
        tol = float(tolerance.get(name, DEFAULT_TOLERANCE))
        d = direction(base.get("derived", ""))
        if d >= 0 and val < ref * (1 - tol):
            failures.append(f"{name}: {val} < {ref} * (1 - {tol}) "
                            f"[{base.get('derived', '')}]")
        if d <= 0 and val > ref * (1 + tol):
            failures.append(f"{name}: {val} > {ref} * (1 + {tol}) "
                            f"[{base.get('derived', '')}]")
    return failures


def load_run(json_dir: str, targets) -> dict[str, dict]:
    """{target: {row_name: row}} from the BENCH_<target>.json artifacts."""
    out = {}
    for target in targets:
        path = os.path.join(json_dir, f"BENCH_{target}.json")
        if not os.path.exists(path):
            out[target] = None  # the whole target failed to produce output
            continue
        with open(path) as f:
            doc = json.load(f)
        out[target] = {r["name"]: r for r in doc["rows"]}
    return out


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", required=True, metavar="DIR",
                    help="directory holding the run's BENCH_<target>.json "
                         "artifacts (benchmarks.run --json DIR)")
    ap.add_argument("--baseline", default=BASELINE_PATH,
                    help="committed baseline to diff against")
    ap.add_argument("--targets", default=",".join(REGRESSION_TARGETS),
                    help="comma-separated target subset")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from this run instead of "
                         "checking (after an intentional perf change)")
    return ap


def main() -> int:
    args = build_parser().parse_args()
    targets = [t for t in args.targets.split(",") if t]
    run = load_run(args.json, targets)

    if args.update:
        base = {"targets": {}, "tolerance": {}}
        if os.path.exists(args.baseline):
            with open(args.baseline) as f:
                base = json.load(f)
        for target, rows in run.items():
            if rows is None:
                print(f"refusing to update: no BENCH_{target}.json in run")
                return 1
            base["targets"][target] = {
                n: {"value": r["value"], "derived": r["derived"]}
                for n, r in rows.items()}
        with open(args.baseline, "w") as f:
            json.dump(base, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"baseline updated: {args.baseline}")
        return 0

    with open(args.baseline) as f:
        base = json.load(f)
    tolerance = base.get("tolerance", {})
    failures: list[str] = []
    for target in targets:
        rows = run[target]
        if rows is None:
            failures.append(f"{target}: BENCH_{target}.json missing "
                            f"(bench crashed or was not run)")
            continue
        base_rows = base["targets"].get(target, {})
        failures.extend(compare_rows(rows, base_rows, tolerance))
        for name in rows:
            if name not in base_rows:
                print(f"NEW (no baseline yet): {name} = {rows[name]['value']}")
    if failures:
        print(f"{len(failures)} benchmark regression(s):")
        for f_ in failures:
            print(f"  REGRESSION {f_}")
        return 1
    print(f"benchmark gate clean: {len(targets)} targets vs {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
