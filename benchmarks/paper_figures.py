"""One benchmark per paper table/figure. Each returns CSV-able rows."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    ROUNDS,
    collect_pseudogradients,
    dp_baseline,
    train_diloco,
)
from repro.core import CompressionConfig, DiLoCoConfig
from repro.core.analysis import interference_gap, per_matrix_cosines


def bench_fig6a_worker_scaling() -> list[dict]:
    """Fig. 1a/6a: % loss increase vs DP baseline as K grows."""
    rows = []
    H = 4
    for inner in ("muon", "adamw"):
        dp = dp_baseline(inner, H=H)
        for K in (1, 2, 4):
            dcfg = DiLoCoConfig(n_workers=K, sync_interval=H, inner_name=inner)
            final, _ = train_diloco(dcfg)
            rows.append({
                "name": f"fig6a/{'muloco' if inner == 'muon' else 'diloco'}/K={K}",
                "value": final,
                "derived": f"pct_vs_dp={100 * (final - dp) / dp:.2f}",
            })
        rows.append({"name": f"fig6a/dp_{inner}", "value": dp, "derived": ""})
    return rows


def bench_fig6b_sync_interval() -> list[dict]:
    """Fig. 6b: K=2, growing H."""
    rows = []
    for inner in ("muon", "adamw"):
        for H in (2, 4, 8):
            dcfg = DiLoCoConfig(n_workers=2, sync_interval=H, inner_name=inner)
            final, _ = train_diloco(dcfg, rounds=max(ROUNDS * 4 // H, 2))
            rows.append({"name": f"fig6b/{inner}/H={H}", "value": final, "derived": ""})
    return rows


def bench_tab5_quantization() -> list[dict]:
    """Tab. 5 / Fig. 7: quantized pseudogradients, linear vs statistical, +-EF."""
    rows = []
    for inner in ("muon", "adamw"):
        base, _ = train_diloco(DiLoCoConfig(n_workers=2, sync_interval=4, inner_name=inner))
        rows.append({"name": f"tab5/{inner}/fp32", "value": base, "derived": ""})
        for mode in ("linear", "statistical"):
            for bits in (8, 4, 2):
                for ef in ((False, True) if (mode == "linear" or bits == 2) else (False,)):
                    # paper Fig. 7: EF is a no-op at >=4 bits; sweep it where it matters
                    comp = CompressionConfig(kind="quant", bits=bits, quant_mode=mode,
                                             error_feedback=ef)
                    dcfg = DiLoCoConfig(n_workers=2, sync_interval=4, inner_name=inner,
                                        compression=comp)
                    final, _ = train_diloco(dcfg)
                    rows.append({
                        "name": f"tab5/{inner}/{mode}/{bits}bit/{'ef' if ef else 'noef'}",
                        "value": final,
                        "derived": f"delta_vs_fp32={final - base:+.4f}",
                    })
    return rows


def bench_tab4_topk() -> list[dict]:
    """Tab. 4 / Fig. 8: top-k sparsification with/without error feedback."""
    rows = []
    for inner in ("muon", "adamw"):
        base, _ = train_diloco(DiLoCoConfig(n_workers=2, sync_interval=4, inner_name=inner))
        rows.append({"name": f"tab4/{inner}/dense", "value": base, "derived": ""})
        for frac in (0.5, 0.1, 0.01):
            for ef in (False, True):
                comp = CompressionConfig(kind="topk", topk_frac=frac, error_feedback=ef,
                                         collective="gather")
                dcfg = DiLoCoConfig(n_workers=2, sync_interval=4, inner_name=inner,
                                    compression=comp)
                final, _ = train_diloco(dcfg)
                rows.append({
                    "name": f"tab4/{inner}/top{int(frac * 100)}pct/{'ef' if ef else 'noef'}",
                    "value": final,
                    "derived": f"delta_vs_dense={final - base:+.4f}",
                })
    return rows


def bench_fig8b_streaming() -> list[dict]:
    """Fig. 8b: streaming (partitioned) sync matches non-streaming."""
    rows = []
    for inner in ("muon", "adamw"):
        for J in (1, 2, 4):
            dcfg = DiLoCoConfig(n_workers=2, sync_interval=4, inner_name=inner,
                                streaming_partitions=J)
            final, _ = train_diloco(dcfg)
            rows.append({"name": f"fig8b/{inner}/J={J}", "value": final, "derived": ""})
    return rows


def bench_fig2_alignment() -> list[dict]:
    """Fig. 2: cosine(pseudogradient_K, pseudogradient_{K=1}) per hidden
    matrix — Muon stays aligned as K grows, AdamW decays with high spread."""
    rows = []
    for inner in ("muon", "adamw"):
        for K in (2, 4):
            _, psi_k, psi_1 = collect_pseudogradients(inner, K)
            cos = per_matrix_cosines(psi_k, psi_1)
            vals = np.array(list(cos.values()))
            rows.append({
                "name": f"fig2/{inner}/K={K}",
                "value": float(vals.mean()),
                "derived": f"std={vals.std():.4f};min={vals.min():.4f}",
            })
    return rows


def bench_fig3_interference() -> list[dict]:
    """Fig. 3: top-S interference gap of worker deltas during averaging."""
    rows = []
    for inner in ("muon", "adamw"):
        for K in (2, 4):
            deltas_k, _, _ = collect_pseudogradients(inner, K)
            w = deltas_k["layers"]["mlp"]["w_in"]  # [K, L, m, n]
            rels = []
            for layer in range(w.shape[1]):
                mats = w[:, layer]
                gap = float(interference_gap(mats, s_frac=0.25))
                # relative gap: fraction of mean worker top-S mass destroyed
                sv = jnp.linalg.svd(mats.astype(jnp.float32), compute_uv=False)
                S = max(int(round(0.25 * sv.shape[-1])), 1)
                mass = float(jnp.mean(jnp.sum(sv[:, :S], axis=-1)))
                rels.append(gap / (mass + 1e-12))
            rows.append({
                "name": f"fig3/{inner}/K={K}",
                "value": float(np.mean(rels)),
                "derived": "relative_topS_interference_gap",
            })
    return rows


def bench_fig5_frobenius() -> list[dict]:
    """Fig. 5: Frobenius norms of *individual inner optimizer steps* —
    Muon's orthonormalized steps have near-constant norm across workers and
    steps; AdamW's vary."""
    rows = []
    for inner in ("muon", "adamw"):
        _, _, _, steps = collect_pseudogradients(inner, K=4, track_steps=True)
        w = steps["mlp"]["w_in"]  # [K, H, L, m, n]
        norms = jnp.sqrt(jnp.sum(w ** 2, axis=(-2, -1)))  # [K, H, L]
        cv = float((jnp.std(norms, axis=(0, 1)) / (jnp.mean(norms, axis=(0, 1)) + 1e-12)).mean())
        rows.append({
            "name": f"fig5/{inner}",
            "value": cv,
            "derived": "step_norm_coef_of_variation",
        })
    return rows


def bench_prop42_identity() -> list[dict]:
    """Prop. 4.2 numeric check on REAL optimizer steps from a toy run."""
    from repro.core.analysis import prop42_nuclear_identity

    deltas_k, _, _ = collect_pseudogradients("muon", K=4, H=1)
    w = deltas_k["layers"]["mlp"]["w_in"][:, 0]  # [K, m, n] single-step deltas
    steps = w[:, None]  # H=1
    lhs, rhs = prop42_nuclear_identity(steps, jnp.ones((1,)))
    return [{"name": "prop42/lhs_rhs_rel_err",
             "value": float(abs(lhs - rhs) / (abs(lhs) + 1e-12)),
             "derived": f"lhs={float(lhs):.4f};rhs={float(rhs):.4f}"}]
