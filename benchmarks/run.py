"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only fig2,tab5
    PYTHONPATH=src python -m benchmarks.run --only kernels --json results/

Prints ``name,value,derived`` CSV rows (and writes results/benchmarks.csv).
``--json DIR`` additionally writes one ``BENCH_<target>.json`` per target —
``{"target", "rows": [{"name", "value", "derived"}, ...], "elapsed_s"}`` —
the machine-readable artifact the CI benchmark-regression tier diffs
against the committed ``benchmarks/baseline.json``
(:mod:`benchmarks.check_regression`).
"""
from __future__ import annotations

import argparse
import csv
import os
import sys
import time


def all_benchmarks():
    from benchmarks import paper_figures as pf
    from benchmarks import systems as sy

    return {
        "fig6a": pf.bench_fig6a_worker_scaling,
        "fig6b": pf.bench_fig6b_sync_interval,
        "tab5": pf.bench_tab5_quantization,
        "tab4": pf.bench_tab4_topk,
        "fig8b": pf.bench_fig8b_streaming,
        "fig2": pf.bench_fig2_alignment,
        "fig3": pf.bench_fig3_interference,
        "fig5": pf.bench_fig5_frobenius,
        "prop42": pf.bench_prop42_identity,
        "train_throughput": sy.bench_train_throughput,
        "serve_bench": sy.bench_serve_throughput,
        "optimizer_bench": sy.bench_optimizer_sweep,
        "compression_bench": sy.bench_compression_sweep,
        "fault_bench": sy.bench_fault_bench,
        "tab10": sy.bench_tab10_wallclock,
        "fig16": sy.bench_fig16_utilization,
        "tab2": sy.bench_tab2_scaling_forms,
        "kernels": sy.bench_kernel_micro,
        "attention_bench": sy.bench_attention_sweep,
        "mesh_kernel_bench": sy.bench_mesh_kernels,
        "roofline": sy.bench_roofline_table,
    }


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset")
    ap.add_argument("--out", default="results/benchmarks.csv")
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="also write one BENCH_<target>.json per target into "
                         "DIR (the regression tier's comparison artifact)")
    return ap


def main() -> None:
    args = build_parser().parse_args()

    benches = all_benchmarks()
    names = args.only.split(",") if args.only else list(benches)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    if args.json:
        os.makedirs(args.json, exist_ok=True)
    rows = []
    print("name,value,derived")
    for name in names:
        t0 = time.time()
        try:
            out = benches[name]()
        except Exception as e:  # noqa: BLE001 — keep the suite going
            print(f"{name}/ERROR,{type(e).__name__},{e}", flush=True)
            continue
        finally:
            # the suite compiles hundreds of distinct programs; without this
            # the XLA CPU JIT eventually fails to materialize new dylibs
            import jax

            jax.clear_caches()
        for row in out:
            print(f"{row['name']},{row['value']},{row['derived']}", flush=True)
            rows.append(row)
        elapsed = time.time() - t0
        print(f"# {name} done in {elapsed:.1f}s", file=sys.stderr, flush=True)
        if args.json:
            import json

            with open(os.path.join(args.json, f"BENCH_{name}.json"), "w") as f:
                json.dump({"target": name, "rows": out,
                           "elapsed_s": round(elapsed, 2)}, f, indent=1)
                f.write("\n")
    with open(args.out, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=["name", "value", "derived"])
        w.writeheader()
        w.writerows(rows)


if __name__ == "__main__":
    main()
