"""Hypothesis property tests over the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.analysis import (  # noqa: E402
    interference_gap,
    nuclear_norm,
    orthonormal_factor,
    prop42_nuclear_identity,
)
from repro.core.compression import (  # noqa: E402
    CompressionConfig,
    ef_compress_tree,
    quantize_linear,
    quantize_statistical,
    topk_sparsify,
)
from repro.optim.muon import newton_schulz  # noqa: E402

SETTINGS = dict(max_examples=20, deadline=None)


def _arr(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


@settings(**SETTINGS)
@given(st.integers(0, 10_000), st.integers(2, 6), st.integers(1, 4),
       st.sampled_from([(8, 12), (16, 16), (12, 8)]))
def test_prop42_nuclear_norm_identity(seed, H, K, mn):
    """Proposition 4.2 is an exact identity for ANY step matrices."""
    m, n = mn
    steps = _arr(seed, (K, H, m, n))
    alphas = jnp.abs(_arr(seed + 1, (H,))) + 0.01
    lhs, rhs = prop42_nuclear_identity(steps, alphas)
    np.testing.assert_allclose(float(lhs), float(rhs), rtol=1e-4)


@settings(**SETTINGS)
@given(st.integers(0, 10_000), st.sampled_from([(16, 16), (8, 24), (24, 8)]))
def test_corollary43_muon_nuclear_norm(seed, mn):
    """For orthonormal steps, ||Psi||_* = (r/K) sum rho*alpha (Cor. 4.3)."""
    m, n = mn
    r = min(m, n)
    K, H = 2, 3
    raw = _arr(seed, (K, H, m, n))
    steps = jnp.stack([jnp.stack([orthonormal_factor(raw[k, h]) for h in range(H)])
                       for k in range(K)])
    alphas = jnp.ones((H,))
    psi = jnp.einsum("h,khmn->mn", alphas, steps) / K
    psi_star = orthonormal_factor(psi)
    rho = jnp.stack([jnp.stack([
        jnp.sum(steps[k, h] * psi_star) / (jnp.sqrt(jnp.float32(r)) * jnp.sqrt(jnp.float32(r)))
        for h in range(H)]) for k in range(K)])
    rhs = r / K * jnp.sum(rho * alphas[None])
    np.testing.assert_allclose(float(nuclear_norm(psi)), float(rhs), rtol=1e-4)


@settings(**SETTINGS)
@given(st.integers(0, 10_000), st.floats(0.05, 0.9))
def test_topk_keeps_exactly_k_largest(seed, frac):
    x = _arr(seed, (23, 31))
    out = topk_sparsify(x, frac)
    k = max(int(round(frac * x.size)), 1)
    nz = int(jnp.sum(out != 0))
    assert nz <= k  # ties / exact zeros can only reduce the count
    # every kept entry is >= every dropped entry in magnitude
    kept = jnp.abs(out[out != 0])
    dropped_mask = (out == 0) & (x != 0)
    if int(jnp.sum(dropped_mask)) and nz:
        assert float(kept.min()) >= float(jnp.abs(jnp.where(dropped_mask, x, 0)).max()) - 1e-6


@settings(**SETTINGS)
@given(st.integers(0, 10_000), st.sampled_from([2, 4, 8]), st.booleans())
def test_linear_quant_error_bound(seed, bits, rowwise):
    x = _arr(seed, (9, 17), scale=3.0)
    out = quantize_linear(x, bits, rowwise)
    nlevels = (1 << bits) - 1
    if rowwise:
        rng = (jnp.max(x, 1, keepdims=True) - jnp.min(x, 1, keepdims=True))
    else:
        rng = jnp.max(x) - jnp.min(x)
    assert bool(jnp.all(jnp.abs(out - x) <= rng / nlevels * 0.5 + 1e-5))


@settings(**SETTINGS)
@given(st.integers(0, 10_000), st.sampled_from([2, 4]))
def test_statistical_quant_uses_codebook_levels(seed, bits):
    x = _arr(seed, (6, 40))
    out = quantize_statistical(x, bits)
    levels = jnp.unique(out)
    assert levels.size <= (1 << bits)


@settings(**SETTINGS)
@given(st.integers(0, 10_000))
def test_error_feedback_conservation(seed):
    """With ef_decay=1: communicated + residual == accumulated deltas."""
    cfg = CompressionConfig(kind="topk", topk_frac=0.3, error_feedback=True, ef_decay=1.0)
    delta = {"a": _arr(seed, (8, 8)), "b": _arr(seed + 1, (5, 7))}
    residual = {"a": _arr(seed + 2, (8, 8), 0.1), "b": jnp.zeros((5, 7))}
    comm, new_res = ef_compress_tree(delta, residual, cfg)
    for k in delta:
        acc = residual[k] + delta[k]
        np.testing.assert_allclose(np.asarray(comm[k] + new_res[k]), np.asarray(acc),
                                   rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(st.integers(0, 10_000), st.sampled_from([(16, 48), (32, 32), (48, 16)]))
def test_newton_schulz_singular_band_and_direction(seed, mn):
    """NS output: singular values in the quintic band; top singular direction
    preserved."""
    m, n = mn
    g = _arr(seed, (m, n))
    o = newton_schulz(g).astype(jnp.float32)
    s = jnp.linalg.svd(o, compute_uv=False)
    # 5 quintic iterations pull singular values into ~[0.1, 1.7] (small
    # trailing values converge slowest for near-singular inputs)
    assert 0.05 < float(s.min()) and float(s.max()) < 1.7
    # alignment with the true orthonormal factor is high
    star = orthonormal_factor(g)
    cos = float(jnp.sum(o * star) / (jnp.linalg.norm(o) * jnp.linalg.norm(star)))
    assert cos > 0.95


@settings(**SETTINGS)
@given(st.integers(0, 10_000), st.integers(2, 6))
def test_interference_gap_nonnegative(seed, K):
    """G_S >= 0 (Def. 4.1: averaging cannot create spectral mass)."""
    mats = _arr(seed, (K, 12, 12))
    g = interference_gap(mats, s_frac=0.3)
    assert float(g) >= -1e-4


@settings(**SETTINGS)
@given(st.integers(0, 10_000))
def test_identical_workers_zero_interference(seed):
    one = _arr(seed, (1, 10, 10))
    mats = jnp.broadcast_to(one, (4, 10, 10))
    g = interference_gap(mats, s_frac=0.5)
    np.testing.assert_allclose(float(g), 0.0, atol=1e-4)


# ---------------------------------------------------------------------------
# Elastic DiLoCo: masks, wire-byte accounting, stragglers
# ---------------------------------------------------------------------------

_ELASTIC_CACHE: dict = {}


def _elastic_engine(K):
    """One compiled elastic engine per K, shared across hypothesis examples
    (engine.step donates its state, so each example re-inits)."""
    if K not in _ELASTIC_CACHE:
        from repro.core import DiLoCoConfig
        from repro.engine import TrainEngine
        from repro.models import ModelConfig, build_model
        from repro.optim import OptimizerConfig

        cfg = ModelConfig(arch_type="dense", n_layers=2, d_model=32, n_heads=2,
                          n_kv_heads=2, d_ff=64, vocab=64, remat=False,
                          dtype="float32", qk_norm=True)
        dcfg = DiLoCoConfig(
            n_workers=K, sync_interval=2, inner_name="adamw", elastic=True,
            compression=CompressionConfig(kind="quant", bits=4, rowwise=True))
        _ELASTIC_CACHE[K] = TrainEngine(build_model(cfg), dcfg, OptimizerConfig(
            lr=1e-2, weight_decay=0.0))
    return _ELASTIC_CACHE[K]


@settings(**SETTINGS)
@given(st.integers(0, 10_000), st.sampled_from([2, 4, 8]), st.data())
def test_masked_round_comm_bytes_equal_dense_times_surviving_fraction(seed, K, data):
    """For ANY participation mask the round's comm_bytes metric is exactly
    the dense measured wire bytes scaled by the surviving fraction —
    dropped workers' packets are never charged."""
    from repro.core.collectives import measured_sync_bytes
    from repro.data import DataConfig, MarkovStream, batches_for_round

    engine = _elastic_engine(K)
    mask = np.asarray(
        data.draw(st.lists(st.sampled_from([0.0, 1.0]), min_size=K, max_size=K)
                  .filter(lambda m: sum(m) > 0)), np.float32)
    state = engine.init(jax.random.PRNGKey(seed % 7))
    dense = measured_sync_bytes(state["outer_params"],
                                engine.dcfg.compression, K)
    stream = MarkovStream(DataConfig(vocab=64, seq_len=16, batch_per_worker=2,
                                     n_workers=K, seed=3))
    _, info = engine.step(state, batches_for_round(stream, 0, 2),
                          participation=mask)
    np.testing.assert_allclose(float(info["comm_bytes"]),
                               dense * (mask.sum() / K), rtol=1e-6)
    assert float(info["active_workers"]) == mask.sum()


@settings(**SETTINGS)
@given(st.sampled_from([2, 4]), st.sampled_from(["none", "quant", "topk"]),
       st.booleans(), st.sampled_from([2, 4, 8]))
def test_streaming_segment_bytes_sum_exactly_to_single_sync(J, kind, rowwise, K):
    """J>1 streaming ships each partition's share: the per-segment measured
    wire bytes sum exactly to the dense single-sync total."""
    from repro.core.collectives import measured_sync_bytes
    from repro.core.streaming import streaming_masks

    params = _streaming_params()
    ccfg = CompressionConfig(kind=kind, bits=4, topk_frac=0.25, rowwise=rowwise,
                             collective="gather" if kind == "topk" else "a2a_rs_ag")
    masks = streaming_masks(params, J)
    per_segment = [measured_sync_bytes(params, ccfg, K, mask=m) for m in masks]
    assert sum(per_segment) == measured_sync_bytes(params, ccfg, K)


def _streaming_params():
    if "params" not in _ELASTIC_CACHE:
        from repro.models import ModelConfig, build_model

        cfg = ModelConfig(arch_type="dense", n_layers=2, d_model=32, n_heads=2,
                          n_kv_heads=2, d_ff=64, vocab=64, remat=False,
                          dtype="float32", qk_norm=True)
        _ELASTIC_CACHE["params"] = build_model(cfg).init(jax.random.PRNGKey(0))
    return _ELASTIC_CACHE["params"]


@settings(**SETTINGS)
@given(st.integers(0, 10_000), st.floats(0.0, 0.9), st.floats(0.0, 0.5),
       st.floats(0.0, 1.0))
def test_straggler_round_times_monotone_in_drop_rate(seed, drop, extra, sigma):
    """Common random numbers: adding drop probability only removes workers
    from the round max, so every sampled round time is non-increasing."""
    from repro.core.wallclock import RunSpec, StragglerModel, straggler_round_times

    spec = RunSpec(n_params=1e6, n_active_params=1e6, batch_tokens=2**12,
                   seq_len=64, n_steps=30, sync_interval=30, n_workers=16)
    t_lo = straggler_round_times(spec, 1e9, StragglerModel(
        sigma=sigma, drop_prob=drop, seed=seed, n_rounds=256))
    t_hi = straggler_round_times(spec, 1e9, StragglerModel(
        sigma=sigma, drop_prob=min(drop + extra, 1.0), seed=seed, n_rounds=256))
    assert np.all(t_hi <= t_lo + 1e-12)


@settings(**SETTINGS)
@given(st.integers(0, 10_000), st.integers(0, 50), st.integers(1, 8),
       st.floats(0.0, 1.0))
def test_fault_plan_chunking_invariance_and_survivor(seed, r0, n, drop):
    """Masks are a pure function of (seed, absolute round) — any chunking of
    the same run sees identical masks — and never drop everyone."""
    from repro.core.faults import FaultPlan

    plan = FaultPlan(n_workers=4, drop_prob=drop, seed=seed)
    stack = plan.masks(r0, n)
    np.testing.assert_array_equal(
        stack, np.stack([plan.mask_for_round(r0 + i) for i in range(n)]))
    assert stack.sum(axis=1).min() >= 1.0
