"""Serving engine tests: page-allocator invariants, paged-vs-dense decode
equality (incl. GQA + sliding window), continuous-batching lifecycle, and
the context-threading regression for cross-attention families.

The decode-equality tests are the serving analogue of
test_models.test_arch_decode_matches_forward: the paged path must
reproduce the dense-cache path bitwise (same dtype, same reduction
order in the XLA gather fallback), so greedy token streams are pinned
identical, not just allclose.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import build_model
from repro.serving import (OutOfPages, PageAllocator, PagedEngine, Request,
                           naive_generate, pages_needed)


# ---------------------------------------------------------------- allocator

def test_allocator_no_double_allocation():
    a = PageAllocator(n_pages=8, page_size=4)
    seen = set(a.alloc("a", 3))
    more = a.alloc("b", 4)
    assert not seen & set(more)
    assert 0 not in seen | set(more)  # null page never handed out
    assert a.n_free == 0


def test_allocator_release_returns_pages():
    a = PageAllocator(n_pages=8, page_size=4)
    a.alloc("a", 3)
    a.alloc("b", 2)
    assert a.n_free == 2
    assert a.release("a") == 3
    assert a.n_free == 5
    assert a.pages_for("a") == []
    # released pages are reusable
    assert len(a.alloc("c", 5)) == 5


def test_allocator_out_of_pages_raises():
    a = PageAllocator(n_pages=4, page_size=4)
    a.alloc("a", 2)
    with pytest.raises(OutOfPages):
        a.alloc("b", 2)
    # failed alloc must not leak pages
    assert a.n_free == 1
    assert a.can_admit(4) and not a.can_admit(5)


def test_allocator_ensure_grows_on_demand():
    a = PageAllocator(n_pages=8, page_size=4)
    a.alloc("a", 1)
    assert a.capacity("a") == 4
    assert a.ensure("a", 4) == []          # already covered
    assert len(a.ensure("a", 9)) == 2      # grow to 3 pages
    assert a.capacity("a") == 12
    assert pages_needed(9, 4) == 3


def test_allocator_page_table_layout():
    a = PageAllocator(n_pages=8, page_size=4)
    pages = a.alloc("a", 2)
    tbl = a.page_table(["a", None], max_pages=4)
    assert tbl.shape == (2, 4) and tbl.dtype == np.int32
    assert tbl[0, :2].tolist() == pages and tbl[0, 2:].tolist() == [0, 0]
    assert tbl[1].tolist() == [0, 0, 0, 0]  # empty slot -> all-null row


# ------------------------------------------------------- paged == dense

def _model(arch="smollm-135m", **overrides):
    cfg = reduce_config(get_config(arch))
    if overrides:
        cfg = cfg.replace(**overrides)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _paged_decode_tokens(model, params, prompts, max_new, impl="xla"):
    """Greedy-decode via paged prefill + per-token paged decode steps."""
    B, P = prompts.shape
    ps = 4
    alloc = PageAllocator(n_pages=1 + B * pages_needed(P + max_new, ps),
                          page_size=ps)
    for b in range(B):
        alloc.alloc(b, pages_needed(P + max_new, ps))
    tbl = jnp.asarray(alloc.page_table(range(B), pages_needed(P + max_new, ps)))
    cache = model.init_paged_cache(alloc.n_pages, ps)
    lens = jnp.full((B,), P, jnp.int32)
    logits, cache = jax.jit(model.paged_prefill)(params, cache, prompts, tbl,
                                                 lens)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    out = [tok]
    step = jax.jit(lambda p, c, t, l: model.paged_decode_step(p, c, t, tbl, l,
                                                              impl=impl))
    for t in range(max_new - 1):
        logits, cache = step(params, cache, tok, lens + t)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    return np.stack([np.asarray(t) for t in out], axis=1), logits


@pytest.mark.parametrize("window", [0, 6])
def test_paged_decode_matches_dense(window):
    """Paged prefill+decode pins the dense-cache greedy stream exactly —
    GQA (reduced smollm is 4 q-heads : 1 kv-head) with and without a
    sliding window."""
    model, params = _model(sliding_window=window)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 7), 0,
                                 model.cfg.vocab)
    dense = np.asarray(naive_generate(model, params, prompts, 6))[:, 7:]
    paged, logits = _paged_decode_tokens(model, params, prompts, 6)
    np.testing.assert_array_equal(paged, dense)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_paged_decode_logits_match_dense_exactly():
    """Per-step logits (not just argmax) are bitwise equal to the dense
    decode path for positions inside the window."""
    model, params = _model()
    B, P, N = 2, 5, 4
    prompts = jax.random.randint(jax.random.PRNGKey(2), (B, P), 0,
                                 model.cfg.vocab)
    # dense reference
    cache = model.init_cache(params, B, P + N)
    logits, cache = jax.jit(model.prefill_with_cache)(params, cache, prompts)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    dense_steps = []
    for t in range(N - 1):
        lg, cache = jax.jit(model.decode_step)(params, cache, tok,
                                               jnp.int32(P + t))
        dense_steps.append(np.asarray(lg))
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
    # paged path
    _, _ = _paged_decode_tokens(model, params, prompts, N)  # smoke
    ps = 4
    alloc = PageAllocator(n_pages=1 + B * pages_needed(P + N, ps), page_size=ps)
    for b in range(B):
        alloc.alloc(b, pages_needed(P + N, ps))
    tbl = jnp.asarray(alloc.page_table(range(B), pages_needed(P + N, ps)))
    pcache = model.init_paged_cache(alloc.n_pages, ps)
    lens = jnp.full((B,), P, jnp.int32)
    lg, pcache = jax.jit(model.paged_prefill)(params, pcache, prompts, tbl, lens)
    tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
    for t in range(N - 1):
        lg, pcache = jax.jit(model.paged_decode_step)(params, pcache, tok, tbl,
                                                      lens + t)
        np.testing.assert_array_equal(np.asarray(lg), dense_steps[t])
        tok = jnp.argmax(lg, -1).astype(jnp.int32)


# ------------------------------------------ kernel vs oracle (both impls)

@pytest.mark.parametrize("window", [0, 5])
def test_paged_decode_attention_matches_oracle(window):
    """`paged_decode_attention` (xla gather fallback AND the Pallas
    scalar-prefetch kernel in interpret mode) against the dense jnp
    oracle, over ragged lengths, null-padded table rows, and GQA."""
    from repro.kernels.flash_attention import paged_decode_attention
    from repro.kernels.ref import paged_attention_ref

    B, H, KV, hd, ps, max_pages = 3, 4, 2, 8, 4, 4
    n_pool = 1 + B * max_pages
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k_pages = jax.random.normal(ks[1], (n_pool, ps, KV, hd), jnp.float32)
    v_pages = jax.random.normal(ks[2], (n_pool, ps, KV, hd), jnp.float32)
    # ragged allocations: slot 0 owns 1 page, slot 1 owns 3, slot 2 all 4;
    # unowned tail entries point at the reserved null page 0
    alloc = PageAllocator(n_pages=n_pool, page_size=ps)
    for b, n in enumerate([1, 3, 4]):
        alloc.alloc(b, n)
    table = jnp.asarray(alloc.page_table(range(B), max_pages))
    lengths = jnp.asarray([2, 11, 16], jnp.int32)  # include current token

    ref = paged_attention_ref(q, k_pages, v_pages, table, lengths,
                              window=window)
    xla = paged_decode_attention(q, k_pages, v_pages, table, lengths,
                                 window=window, impl="xla")
    pal = paged_decode_attention(q, k_pages, v_pages, table, lengths,
                                 window=window, impl="pallas", interpret=True)
    assert np.all(np.isfinite(np.asarray(ref)))
    np.testing.assert_allclose(np.asarray(xla), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------- continuous batching

def test_engine_matches_naive_batch():
    model, params = _model()
    prompts = jax.random.randint(jax.random.PRNGKey(3), (3, 6), 0,
                                 model.cfg.vocab)
    ref = np.asarray(naive_generate(model, params, prompts, 8))[:, 6:]
    eng = PagedEngine(model, params, slots=2, page_size=4, max_pages=32,
                      decode_steps_per_dispatch=3)
    reqs = [Request(f"r{i}", tuple(int(t) for t in row), 8)
            for i, row in enumerate(np.asarray(prompts))]
    out = eng.run(reqs)
    for i in range(3):
        np.testing.assert_array_equal(out[f"r{i}"], ref[i])


def test_engine_late_join_matches_solo():
    """A request admitted mid-flight (staggered arrivals, varying prompt
    lengths and max_new) produces exactly the tokens of a solo decode."""
    model, params = _model()
    prompts = [tuple(int(t) for t in np.asarray(jax.random.randint(
        jax.random.PRNGKey(10 + i), (L,), 0, model.cfg.vocab)))
        for i, L in enumerate([3, 9, 5])]
    eng = PagedEngine(model, params, slots=2, page_size=4, max_pages=32,
                      decode_steps_per_dispatch=2)
    reqs = [Request(f"s{i}", p, [7, 4, 9][i], arrival=[0, 1, 4][i])
            for i, p in enumerate(prompts)]
    out = eng.run(reqs)
    for i, p in enumerate(prompts):
        solo = np.asarray(naive_generate(
            model, params, jnp.asarray([p], jnp.int32), reqs[i].max_new))
        np.testing.assert_array_equal(out[f"s{i}"], solo[0, len(p):])


def test_engine_releases_pages_and_rejects_oversized():
    model, params = _model()
    eng = PagedEngine(model, params, slots=1, page_size=4, max_pages=8,
                      decode_steps_per_dispatch=2)
    # sequential requests through one slot: pool must be fully recycled
    reqs = [Request(f"q{i}", (1, 2, 3), 4) for i in range(3)]
    out = eng.run(reqs)
    assert sorted(out) == ["q0", "q1", "q2"]
    ref = out["q0"]
    for rid in ("q1", "q2"):
        np.testing.assert_array_equal(out[rid], ref)  # identical prompts
    # a request that can never fit raises instead of deadlocking
    big = Request("big", tuple(range(1, 40)), 8)
    with pytest.raises(OutOfPages):
        eng.run([big])


def test_engine_requires_paged_support():
    model, params = _model("mamba2-370m")
    with pytest.raises(ValueError, match="naive"):
        PagedEngine(model, params)


# --------------------------------------------- context threading regression

@pytest.mark.parametrize("arch", ["whisper-large-v3", "llama-3.2-vision-90b"])
def test_generate_threads_context(arch):
    """Regression: serve-path generate() must condition decode on the
    request context (the seed dropped it — audio/VLM decode ran
    unconditioned, so changing the context changed nothing)."""
    from repro.launch.serve import generate

    model, params = _model(arch)
    cfg = model.cfg
    if cfg.arch_type == "vlm":
        # open the Flamingo-style tanh gates (zero-init => cross path is
        # exactly zero at init and context could not influence logits)
        params["cross_layers"]["attn"]["gate"] = jnp.ones_like(
            params["cross_layers"]["attn"]["gate"])
        params["cross_layers"]["mlp_gate"] = jnp.ones_like(
            params["cross_layers"]["mlp_gate"])
        nctx = cfg.n_image_tokens
    else:
        nctx = cfg.n_audio_frames
    prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 5), 0, cfg.vocab)
    ctx_a = jax.random.normal(jax.random.PRNGKey(1), (2, nctx, cfg.d_model))
    ctx_b = jax.random.normal(jax.random.PRNGKey(2), (2, nctx, cfg.d_model))
    out_a = np.asarray(generate(model, params, prompts, 6, context=ctx_a))
    out_a2 = np.asarray(generate(model, params, prompts, 6, context=ctx_a))
    out_b = np.asarray(generate(model, params, prompts, 6, context=ctx_b))
    np.testing.assert_array_equal(out_a, out_a2)      # deterministic
    assert not np.array_equal(out_a[:, 5:], out_b[:, 5:])


def test_naive_generate_batched_prefill_matches_stepped():
    """The single-dispatch batched prefill is a pure execution change:
    greedy streams match the token-stepped prefill exactly."""
    model, params = _model()
    prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 9), 0,
                                 model.cfg.vocab)
    a = np.asarray(naive_generate(model, params, prompts, 5,
                                  batched_prefill=True))
    b = np.asarray(naive_generate(model, params, prompts, 5,
                                  batched_prefill=False))
    np.testing.assert_array_equal(a, b)
