"""Crash-safe training: checksummed checkpoints, the health sentinel, and
automatic rollback/resume.

Invariants under test:

* every checkpoint leaf carries a CRC32; a flipped bit on disk raises
  ``CheckpointError`` instead of loading silently-corrupt weights;
* a zero-length file (torn write caught at its worst) is classified invalid;
* ``load_latest_valid`` walks newest -> oldest past truncated/corrupted/empty
  files to the newest checkpoint that verifies, and returns None when none do;
* round-stamped retention keeps exactly ``keep`` files and the ``LATEST``
  manifest stays consistent with the directory;
* the health sentinel flags non-finite losses/psi and EMA loss spikes with
  distinct bits, and stays a None no-op when disabled (the bit-parity story);
* an injected NaN round is rolled back to the last valid checkpoint, the
  offending span is skipped (seed-keyed data makes skipping = advancing the
  round counter), and the run completes with finite losses;
* a restore that has nothing to offer escalates to ``TrainingAborted``;
* ``should_stop`` preemption drains in-flight work and leaves a state that
  resumes to the bitwise-identical uninterrupted trajectory;
* the keystone: SIGKILL the train CLI at an arbitrary round, resume with
  ``--resume auto``, and metrics.csv (minus the wall-clock column) is
  byte-identical to the uninterrupted run's — for BOTH inner optimizers.
"""
import csv
import os
import signal
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointError,
    checkpoint_path,
    list_checkpoints,
    load_checkpoint,
    load_latest_valid,
    read_manifest,
    save_checkpoint,
    save_round_checkpoint,
)
from repro.core import DiLoCoConfig, HealthConfig, health_init, health_update
from repro.core.faults import CrashPlan, corrupt_file, truncate_file
from repro.data import DataConfig, MarkovStream, batches_for_round, batches_for_span
from repro.engine import RecoveryPolicy, TrainEngine, TrainingAborted, run_rounds
from repro.models import ModelConfig, build_model
from repro.optim import OptimizerConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Checksummed checkpoint files
# ---------------------------------------------------------------------------


def _tree(seed=0, big=False):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(k1, (128, 128) if big else (4, 3))
    return {"w": w, "inner": {"b": jax.random.normal(k2, (5,)),
                              "n": jnp.arange(4, dtype=jnp.int32)}}


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_checksum_roundtrip(tmp_path):
    tree = _tree()
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, tree, step=7)
    loaded, step = load_checkpoint(path, tree)
    assert step == 7
    _assert_trees_equal(tree, loaded)


def test_on_disk_bit_flip_raises_checkpoint_error(tmp_path):
    # one big leaf dominates the archive, so a mid-file flip lands in array
    # payload; whichever CRC layer catches it (zip member or our meta), the
    # caller sees the one unified invalid-checkpoint signal
    tree = _tree(big=True)
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, tree, step=3)
    corrupt_file(path, offset=os.path.getsize(path) // 2)
    with pytest.raises(CheckpointError):
        load_checkpoint(path, tree)


def test_leaf_checksum_catches_tamper_behind_valid_zip(tmp_path):
    # re-zip the archive with one payload byte flipped: the zip structure and
    # member CRCs are freshly valid, so only the per-leaf checksum stored in
    # the meta record can notice the weights changed since save time
    tree = _tree()
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, tree, step=3)
    with np.load(path) as z:
        members = {k: np.array(z[k]) for k in z.files}
    leaf = next(k for k in members if k.startswith("leaf_"))
    members[leaf].view(np.uint8).reshape(-1)[0] ^= 0xFF
    np.savez(path, **members)
    with pytest.raises(CheckpointError, match="checksum mismatch"):
        load_checkpoint(path, tree)
    assert load_checkpoint(path, tree, verify=False)  # opt-out still loads


def test_zero_length_file_is_invalid(tmp_path):
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, _tree(), step=1)
    truncate_file(path, keep_bytes=0)
    with pytest.raises(CheckpointError):
        load_checkpoint(path, _tree())


# ---------------------------------------------------------------------------
# Retention + LATEST manifest + newest-valid fallback
# ---------------------------------------------------------------------------


def test_retention_prunes_to_keep_and_manifest_tracks(tmp_path):
    d = str(tmp_path)
    for r in (2, 4, 6, 8):
        save_round_checkpoint(d, _tree(seed=r), r, keep=2)
    names = [os.path.basename(p) for _, p in list_checkpoints(d)]
    assert names == ["ckpt_8.npz", "ckpt_6.npz"]
    man = read_manifest(d)
    assert man["latest"] == "ckpt_8.npz" and man["round"] == 8
    assert sorted(man["retained"]) == ["ckpt_6.npz", "ckpt_8.npz"]
    # checkpoint_path is the naming contract list_checkpoints parses back
    assert checkpoint_path(d, 8) == os.path.join(d, "ckpt_8.npz")


@pytest.mark.parametrize("damage", [
    lambda p: truncate_file(p, keep_bytes=100),
    lambda p: truncate_file(p, keep_bytes=0),
    lambda p: corrupt_file(p, offset=os.path.getsize(p) // 2),
], ids=["truncated", "zero-length", "bit-flipped"])
def test_load_latest_valid_falls_back_past_damaged_newest(tmp_path, damage):
    d = str(tmp_path)
    good = _tree(seed=4, big=True)
    save_round_checkpoint(d, _tree(seed=2, big=True), 2, keep=3)
    save_round_checkpoint(d, good, 4, keep=3)
    save_round_checkpoint(d, _tree(seed=6, big=True), 6, keep=3)
    damage(checkpoint_path(d, 6))
    tree, step, path = load_latest_valid(d, good)
    assert step == 4 and os.path.basename(path) == "ckpt_4.npz"
    _assert_trees_equal(good, tree)


def test_load_latest_valid_returns_none_when_all_damaged(tmp_path):
    d = str(tmp_path)
    for r in (2, 4):
        save_round_checkpoint(d, _tree(seed=r, big=True), r, keep=3)
        corrupt_file(checkpoint_path(d, r),
                     offset=os.path.getsize(checkpoint_path(d, r)) // 2)
    assert load_latest_valid(d, _tree(big=True)) is None
    assert load_latest_valid(str(tmp_path / "missing"), _tree()) is None


# ---------------------------------------------------------------------------
# Health sentinel unit behaviour
# ---------------------------------------------------------------------------

_HCFG = HealthConfig(enabled=True, spike_factor=3.0, ema_alpha=0.2,
                     warmup_rounds=2)


def _step(health, losses, psi_val=0.0):
    losses = jnp.asarray(losses, jnp.float32)
    psi = {"w": jnp.full((2,), psi_val, jnp.float32)}
    health, flag = health_update(_HCFG, health, losses, psi)
    return health, int(flag)


def test_health_disabled_is_none_and_noop():
    assert health_init(HealthConfig()) is None  # default: off, no state leaf


def test_health_flags_nonfinite_loss_and_psi():
    h = health_init(_HCFG)
    h, flag = _step(h, [1.0, jnp.nan])
    assert flag & 1  # FLAG_NONFINITE_LOSS
    h, flag = _step(h, [1.0, 1.0], psi_val=jnp.inf)
    assert flag & 2  # FLAG_NONFINITE_PSI
    h, flag = _step(h, [1.0, 1.0])
    assert flag == 0


def test_health_spike_fires_only_after_warmup():
    h = health_init(_HCFG)
    h, flag = _step(h, [100.0, 100.0])  # round 0: would-be spike, in warmup
    assert flag == 0
    h = health_init(_HCFG)
    for _ in range(3):
        h, flag = _step(h, [2.0, 2.0])
        assert flag == 0
    h, flag = _step(h, [20.0, 20.0])  # 10x the EMA, past warmup
    assert flag & 4  # FLAG_LOSS_SPIKE
    # the EMA ignores the spiked round's mean only when non-finite; a finite
    # spike still updates it, so a persistent plateau stops flagging
    for _ in range(8):
        h, flag = _step(h, [20.0, 20.0])
    assert flag == 0


# ---------------------------------------------------------------------------
# Driver-level rollback / escalation / preemption
# ---------------------------------------------------------------------------

_CFG = ModelConfig(arch_type="dense", n_layers=2, d_model=32, n_heads=2,
                   n_kv_heads=2, d_ff=64, vocab=64, remat=False,
                   dtype="float32", qk_norm=True)


def _engine(health=False):
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=2, inner_name="adamw",
                        health=HealthConfig(enabled=health, warmup_rounds=1))
    engine = TrainEngine(build_model(_CFG), dcfg,
                         OptimizerConfig(lr=1e-2, weight_decay=0.0))
    return engine, engine.init(jax.random.PRNGKey(0))


def _data():
    return MarkovStream(DataConfig(vocab=_CFG.vocab, seq_len=16,
                                   batch_per_worker=2, n_workers=2, seed=3))


def _run(engine, state, rounds, start=0, **kw):
    data = _data()
    return run_rounds(
        engine, state, lambda r: batches_for_round(data, r, 2),
        rounds, start=start, rounds_per_dispatch=1,
        span_batches_for=lambda r0, n: batches_for_span(data, r0, 2, n), **kw)


def test_nan_fault_rolls_back_and_skips_offending_round(tmp_path):
    engine, state = _engine(health=True)
    d = str(tmp_path)
    save_round_checkpoint(d, state, 0)
    crash = CrashPlan(nan_round=2)
    telemetry: dict = {}
    recovery = RecoveryPolicy(
        restore=lambda: load_latest_valid(d, engine.abstract_state())[:2])
    state, history = _run(
        engine, state, 4, telemetry=telemetry, recovery=recovery,
        inject=crash.apply,
        on_state=lambda r, st: save_round_checkpoint(d, st, r + 1),
        on_state_every=1)
    assert [h["round"] for h in history] == [0, 1, 3]  # round 2 skipped
    assert telemetry["rollbacks"] == 1
    assert telemetry["skipped_rounds"] == 1  # rolled ckpt_2 -> resumed at 3
    assert all(np.isfinite(h["train_loss"]) for h in history)
    assert int(jax.device_get(state["round"])) == 4


def test_recovery_without_valid_checkpoint_aborts():
    engine, state = _engine(health=True)
    recovery = RecoveryPolicy(restore=lambda: None)
    with pytest.raises(TrainingAborted):
        _run(engine, state, 3, recovery=recovery,
             inject=CrashPlan(nan_round=1).apply, telemetry={})


def test_escalation_exhausts_rollbacks_then_aborts(tmp_path):
    # the checkpoint itself is re-poisoned by the injector every round, so
    # every retry flags again: max_rollbacks must bound the loop and (with no
    # scale_lr escape hatch) end in TrainingAborted, not an infinite loop
    engine, state = _engine(health=True)
    d = str(tmp_path)
    save_round_checkpoint(d, state, 0)
    always = CrashPlan(nan_round=0)
    recovery = RecoveryPolicy(
        restore=lambda: load_latest_valid(d, engine.abstract_state())[:2],
        max_rollbacks=2)
    telemetry: dict = {}
    with pytest.raises(TrainingAborted):
        _run(engine, state, 3, recovery=recovery, telemetry=telemetry,
             inject=lambda r0, n, b, s: always.apply(0, n, b, s))
    assert telemetry["rollbacks"] == 2


def test_should_stop_preempts_and_resumes_bitwise():
    engine, state = _engine()
    full_hist = _run(engine, engine.init(jax.random.PRNGKey(0)), 4)[1]

    probes = iter([False, False, True])  # stop before the third dispatch
    telemetry: dict = {}
    state, hist = _run(engine, state, 4, telemetry=telemetry,
                       should_stop=lambda: next(probes, True))
    assert telemetry["preempted"] is True
    done = int(jax.device_get(state["round"]))
    assert done == 2 and [h["round"] for h in hist] == [0, 1]

    state, tail = _run(engine, state, 4, start=done)
    assert [h["round"] for h in tail] == [2, 3]
    for a, b in zip(full_hist, hist + tail):
        assert a["train_loss"] == b["train_loss"]  # bitwise, not approx


# ---------------------------------------------------------------------------
# Train CLI end-to-end: NaN rollback, SIGKILL keystone, SIGTERM preemption
# ---------------------------------------------------------------------------

_BASE = ["--reduced", "--inner", "adamw", "--lr", "4e-3", "--workers", "2",
         "--sync-interval", "2", "--rounds", "6", "--batch-per-worker", "2",
         "--seq-len", "32", "--seed", "0", "--checkpoint-every", "2"]


def test_train_cli_nan_injection_rolls_back_and_completes(tmp_path):
    from repro.launch.train import build_parser, train

    out = train(build_parser().parse_args(
        _BASE + ["--health-sentinel", "on", "--inject-nan-round", "3",
                 "--out", str(tmp_path)]))
    assert out["telemetry"]["rollbacks"] == 1
    assert out["telemetry"]["skipped_rounds"] == 2  # ckpt_2 -> resume at 4
    assert np.isfinite(out["final_loss"])
    with open(tmp_path / "metrics.csv", newline="") as f:
        rows = list(csv.DictReader(f))
    assert [int(r["round"]) for r in rows] == [0, 1, 2, 4, 5]
    assert all(r["health"] == "0" for r in rows)  # flagged round never logged
    assert rows[-1]["rollbacks"] == "1"


def _cli(args, out, env):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.train", *args, "--out", str(out)],
        capture_output=True, text=True, env=env, cwd=REPO)


def _env():
    return {**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
            "JAX_PLATFORMS": "cpu"}


def _rows_sans_wall(path):
    with open(path, newline="") as f:
        return [row[:-1] for row in csv.reader(f)]  # wall_s is the last col


@pytest.mark.parametrize("inner", ["adamw", "muon"])
def test_sigkill_resume_metrics_tail_bitwise(tmp_path, inner):
    """The keystone invariant: SIGKILL at round 3, --resume auto, and the
    full metrics.csv (minus wall-clock) is byte-identical to an
    uninterrupted run's — crash + recovery invisible to the arithmetic."""
    env = _env()
    base = [a if a != "adamw" else inner for a in _BASE]
    ref = _cli(base, tmp_path / "ref", env)
    assert ref.returncode == 0, ref.stderr

    killed = _cli(base + ["--inject-kill-round", "3"], tmp_path / "crash", env)
    assert killed.returncode == -signal.SIGKILL
    assert os.path.exists(tmp_path / "crash" / "ckpt_2.npz")

    resumed = _cli(base + ["--resume", "auto"], tmp_path / "crash", env)
    assert resumed.returncode == 0, resumed.stderr
    assert ("resume telemetry: resumed_from=ckpt_2.npz start_round=2"
            in resumed.stdout)
    assert (_rows_sans_wall(tmp_path / "crash" / "metrics.csv")
            == _rows_sans_wall(tmp_path / "ref" / "metrics.csv"))


def test_sigterm_preempts_with_resumable_checkpoint(tmp_path):
    """SIGTERM mid-run: the handler drains in-flight dispatches, reports
    preemption, exits 0 with a final checkpoint; --resume auto completes the
    remaining rounds."""
    env = _env()
    args = [a if a != "6" else "200" for a in _BASE] + [
        "--rounds-per-dispatch", "1", "--checkpoint-every", "1",
        "--keep-checkpoints", "2", "--out", str(tmp_path)]
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train", *args],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env, cwd=REPO)
    csv_path = tmp_path / "metrics.csv"
    deadline = time.time() + 180
    while time.time() < deadline and proc.poll() is None:
        if csv_path.exists() and len(csv_path.read_text().splitlines()) >= 3:
            break
        time.sleep(0.2)
    if proc.poll() is not None:
        proc.communicate()
        pytest.skip("run finished before SIGTERM could land")
    proc.send_signal(signal.SIGTERM)
    stdout, _ = proc.communicate(timeout=300)
    if "preempted after round" not in stdout:
        pytest.skip("SIGTERM landed after the final dispatch")
    assert proc.returncode == 0, stdout
    assert "preempted=True" in stdout
    assert list_checkpoints(str(tmp_path)), "no resumable checkpoint on disk"

    resumed = _cli(args[:-2] + ["--resume", "auto"], tmp_path, env)
    assert resumed.returncode == 0, resumed.stderr
    assert "resume telemetry: resumed_from=" in resumed.stdout
    rows = _rows_sans_wall(csv_path)
    assert int(rows[-1][0]) == 199  # header + all 200 rounds present
    assert [int(r[0]) for r in rows[1:]] == list(range(200))


def test_crash_plan_dispatch_pinning():
    assert CrashPlan().is_trivial
    assert not CrashPlan(kill_round=3).needs_single_round_dispatch
    assert CrashPlan(nan_round=1).needs_single_round_dispatch
    assert CrashPlan(spike_round=1).needs_single_round_dispatch
