"""Data pipeline, checkpointing, scaling laws, wallclock model, roofline
parsers, streaming masks — the supporting substrate."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core.scaling_laws import (
    fit_power_law,
    iso_loss_time_ratio,
    optimal_and_critical_batch,
)
from repro.core.wallclock import RunSpec, compute_utilization, training_time_hours
from repro.data import DataConfig, MarkovStream, batches_for_round
from repro.roofline.analysis import RooflineTerms, parse_collective_bytes
from repro.roofline.hlo import collective_bytes_corrected


def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab=64, seq_len=16, batch_per_worker=2, n_workers=3, seed=7)
    s1, s2 = MarkovStream(cfg), MarkovStream(cfg)
    b1, b2 = s1.batch(5), s2.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert b1["tokens"].shape == (3, 2, 16)
    # labels are next tokens
    np.testing.assert_array_equal(np.asarray(b1["labels"][..., :-1]),
                                  np.asarray(b1["tokens"][..., 1:]))
    # different workers get different data
    assert not np.array_equal(np.asarray(b1["tokens"][0]), np.asarray(b1["tokens"][1]))
    # different steps differ
    assert not np.array_equal(np.asarray(s1.batch(6)["tokens"]), np.asarray(b1["tokens"]))


def test_data_has_learnable_structure():
    """Chain entropy floor is far below uniform -> the data is learnable."""
    cfg = DataConfig(vocab=256, branching=8)
    s = MarkovStream(cfg)
    assert s.entropy_floor_nats() < 0.5 * np.log(cfg.vocab)


def test_round_batches_shape():
    cfg = DataConfig(vocab=64, seq_len=16, batch_per_worker=2, n_workers=2)
    s = MarkovStream(cfg)
    b = batches_for_round(s, 0, 4)
    assert b["tokens"].shape == (4, 2, 2, 16)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16), "d": jnp.int32(7)}}
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, tree, step=42)
    restored, step = load_checkpoint(path, tree)
    assert step == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_structure_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "c.npz")
    save_checkpoint(path, {"a": jnp.ones(3)})
    with pytest.raises(ValueError):
        load_checkpoint(path, {"zz": jnp.ones(3)})


def test_power_law_fit_recovers_parameters():
    C = np.logspace(18, 22, 8)
    L = 5e3 * C ** -0.2 + 1.7
    fit = fit_power_law(C, L, irr=1.7, restarts=32)
    assert abs(fit.alpha + 0.2) < 0.01
    assert abs(fit.a / 5e3 - 1) < 0.05


def test_optimal_and_critical_batch():
    batches = [32, 64, 128, 256, 512, 1024]
    # loss min at 128, rises past it
    losses = [3.2, 3.05, 3.0, 3.01, 3.02, 3.2]
    b_opt, b_crit = optimal_and_critical_batch(batches, losses, tol=0.01)
    assert b_opt == 128
    assert 512 <= b_crit <= 1024


def test_iso_loss_ratio_decomposition():
    from repro.core.scaling_laws import PowerLawFit

    ref_loss = PowerLawFit(a=6e3, alpha=-0.19, irr=1.7, objective=0)
    m_loss = PowerLawFit(a=6e3, alpha=-0.20, irr=1.7, objective=0)
    ref_cbs = PowerLawFit(a=1e3, alpha=0.3, irr=0, objective=0)
    m_cbs = PowerLawFit(a=2e3, alpha=0.35, irr=0, objective=0)
    out = iso_loss_time_ratio(ref_loss, ref_cbs, m_loss, m_cbs, target_loss=2.2)
    np.testing.assert_allclose(out["time_ratio"],
                               out["compute_savings"] * out["parallelism_advantage"],
                               rtol=1e-6)
    assert out["time_ratio"] > 1.0  # better exponent + bigger CBS -> faster


def test_wallclock_diloco_beats_dp_at_low_bandwidth():
    """Paper Fig. 16/Tab. 10: communication-efficient training dominates at
    10 Gbit/s; the gap shrinks at datacenter bandwidth."""
    base = dict(n_params=15e9, n_active_params=15e9, batch_tokens=4e6,
                seq_len=2048, n_steps=10_000)
    dp = RunSpec(**base, sync_interval=1)
    diloco = RunSpec(**base, sync_interval=30, n_workers=16)
    lo, hi = 10e9, 12_800e9
    assert training_time_hours(diloco, lo) < 0.2 * training_time_hours(dp, lo)
    ratio_hi = training_time_hours(diloco, hi) / training_time_hours(dp, hi)
    assert 0.9 < ratio_hi <= 1.0
    assert compute_utilization(diloco, lo) > compute_utilization(dp, lo)


def test_quantization_cuts_wire_time():
    from repro.core.compression import CompressionConfig

    spec = RunSpec(n_params=3e9, n_active_params=3e9, batch_tokens=2e6, seq_len=2048,
                   n_steps=1000, sync_interval=30,
                   compression_ratio=CompressionConfig(kind="quant", bits=4).compression_ratio())
    dense = RunSpec(n_params=3e9, n_active_params=3e9, batch_tokens=2e6, seq_len=2048,
                    n_steps=1000, sync_interval=30)
    assert training_time_hours(spec, 10e9) < training_time_hours(dense, 10e9)


HLO_SAMPLE = """
HloModule test

%body.1 (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %ag = f32[128,256]{1,0} all-gather(f32[8,256]{1,0} %x), dimensions={0}
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %ag), to_apply=%sum
}

%cond.1 (p: (s32[], f32[128,256])) -> pred[] {
  %c = s32[] constant(12)
  %cmp = pred[] compare(s32[] %i, s32[] %c), direction=LT
}

ENTRY %main () -> f32[128,256] {
  %w = (s32[], f32[128,256]) while((s32[], f32[128,256]) %init), condition=%cond.1, body=%body.1
  %ag2 = f32[64,64]{1,0} all-gather(f32[4,64]{1,0} %y), dimensions={0}
}
"""


def test_collective_parser_flat():
    out = parse_collective_bytes(HLO_SAMPLE)
    expected = 128 * 256 * 4 * 2 + 64 * 64 * 4
    assert out["total"] == expected


def test_collective_parser_loop_corrected():
    out = collective_bytes_corrected(HLO_SAMPLE)
    in_loop = 128 * 256 * 4 * 2
    assert out["total"] == in_loop * 12 + 64 * 64 * 4
    assert out["flat_total"] == in_loop + 64 * 64 * 4


def test_roofline_terms_dominant():
    t = RooflineTerms(flops=197e12, hlo_bytes=0, collective_bytes=0, chips=256,
                      model_flops=197e12 * 256)
    assert t.dominant == "compute"
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.useful_flops_ratio - 1.0) < 1e-9
    t2 = RooflineTerms(flops=0, hlo_bytes=0, collective_bytes=50e9, chips=256,
                       model_flops=0, amortize=30)
    assert t2.dominant == "collective"
    assert abs(t2.collective_s - 1 / 30) < 1e-9
