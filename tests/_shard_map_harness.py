"""Child process for tests/test_shard_map.py (NOT a test file itself).

Forces an 8-host-device world BEFORE importing jax, builds the
(pod=2, data=2, model=2) debug mesh, and runs every kernel three ways in
this one process:

* plain jit with no routing installed — on an 8-device world this executes
  on device 0 only, i.e. it IS the single-device Pallas path;
* jit under ``kernel_partitioning(kernel_specs(mesh))`` inside the mesh —
  the shard_mapped multi-device path;
* the jitted jnp oracle from :mod:`repro.kernels.ref`.

The shard_mapped outputs must be **bitwise** equal to the single-device
Pallas outputs (padding happens inside the mapped region on local shapes,
so sharding never changes any element's arithmetic) and allclose to the
oracle. The flash VJP runs under the production composition —
``vmap(spmd_axis_name='pod')`` over workers + ``lax.scan`` + ``remat`` —
and asserts the batch-local grads (dq/dk/dv) bitwise.

Prints one JSON object on the last stdout line.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# ruff: noqa: E402  (XLA_FLAGS must precede any jax-touching import)
import json

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.kernels import ops, ref
from repro.kernels.flash_attention import gqa_flash_attention, paged_decode_attention
from repro.kernels.partition import kernel_partitioning
from repro.launch.mesh import make_debug_mesh
from repro.launch.sharding import kernel_specs

MESH = make_debug_mesh(data=2, model=2, pod=2)
PARTS = kernel_specs(MESH)


def bitwise(a, b) -> bool:
    return all(
        bool((np.asarray(x) == np.asarray(y)).all())
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def close(a, b, tol=2e-5) -> bool:
    return all(
        bool(np.allclose(np.asarray(x), np.asarray(y), rtol=tol, atol=tol))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def sharded(fn, *args):
    """Run ``jit(fn)`` with the kernel routing installed on the mesh."""
    with kernel_partitioning(PARTS), MESH:
        return jax.tree.map(lambda x: np.asarray(x), jax.jit(fn)(*args))


def single(fn, *args):
    """Plain jit, no routing: the single-device Pallas path (device 0)."""
    return jax.tree.map(lambda x: np.asarray(x), jax.jit(fn)(*args))


def main() -> dict:
    out: dict = {"devices": jax.device_count(),
                 "mesh": dict(zip(MESH.axis_names, MESH.devices.shape))}
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)

    # -- flash attention forward -------------------------------------------
    B, S, H, KV, hd = 4, 64, 4, 2, 16
    q = jax.random.normal(k1, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(k2, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(k3, (B, S, KV, hd), jnp.float32)

    def flash(q, k, v):
        return gqa_flash_attention(q, k, v, causal=True, block_q=16, block_kv=32)

    one = single(flash, q, k, v)
    out["flash_fwd"] = {
        "bitwise": bitwise(sharded(flash, q, k, v), one),
        "vs_ref": close(one, single(
            lambda q, k, v: ref.gqa_attention_ref(q, k, v, causal=True), q, k, v)),
    }

    # -- flash VJP under vmap(spmd)+scan+remat -----------------------------
    Kw = 2
    qk = jax.random.normal(k1, (Kw, B, S, H, hd), jnp.float32)
    kk = jax.random.normal(k2, (Kw, B, S, KV, hd), jnp.float32)
    vk = jax.random.normal(k3, (Kw, B, S, KV, hd), jnp.float32)

    def loss_one(q, k, v):
        @jax.checkpoint
        def step(c, _):
            return c + jnp.sum(flash(q, k, v) ** 2), None

        tot, _ = jax.lax.scan(step, 0.0, jnp.arange(2))
        return tot

    def grads(spmd):
        g = jax.grad(loss_one, argnums=(0, 1, 2))
        return (jax.vmap(g, spmd_axis_name=spmd) if spmd else jax.vmap(g))

    gref = single(grads(None), qk, kk, vk)
    with kernel_partitioning(PARTS), MESH:
        shard = NamedSharding(MESH, P("pod"))
        args = [jax.device_put(x, shard) for x in (qk, kk, vk)]
        gout = jax.tree.map(lambda x: np.asarray(x),
                            jax.jit(grads("pod"))(*args))
    out["flash_vjp"] = {
        name: bool((a == b).all())
        for name, a, b in zip(("dq", "dk", "dv"), gref, gout)}
    out["flash_vjp"]["bitwise"] = all(out["flash_vjp"].values())

    # -- wire quantize / dequantize ----------------------------------------
    x = jax.random.normal(k1, (32, 40), jnp.float32)

    def quant(x):
        return ops.quantize_rowwise(x, bits=4)

    rq = single(quant, x)
    deq_ref, _, lo_ref, scale_ref = single(
        lambda x: ref.rowwise_quantize_ref(x, 4), x)
    out["quantize"] = {
        "bitwise": bitwise(sharded(quant, x), rq),
        "vs_ref": close((rq[0], rq[2], rq[3]), (deq_ref, lo_ref, scale_ref)),
    }

    def deq(c, lo, s):
        return ops.dequantize_rowwise(c, lo, s)

    rd = single(deq, rq[1], rq[2], rq[3])
    out["dequantize"] = {
        "bitwise": bitwise(sharded(deq, rq[1], rq[2], rq[3]), rd),
        "vs_ref": close(rd, single(ref.rowwise_dequantize_ref,
                                   rq[1], rq[2], rq[3])),
    }

    # -- Newton-Schulz (L=4 stack: local bsz 2 on the 2-way 'data' axis,
    #    so BOTH paths take _ns_stack's vmap branch) ------------------------
    g = jax.random.normal(k2, (4, 24, 16), jnp.float32)

    def ns(g):
        return ops.ns_orthogonalize(g, block=8)

    rn = single(ns, g)
    out["ns_orthogonalize"] = {
        "bitwise": bitwise(sharded(ns, g), rn),
        "vs_ref": close(rn, single(ref.ns_orthogonalize_ref, g), tol=5e-2),
    }

    # -- fused outer update -------------------------------------------------
    t = jax.random.normal(k1, (24, 32), jnp.float32)
    p = jax.random.normal(k2, (24, 32), jnp.float32)
    u = jax.random.normal(k3, (24, 32), jnp.float32)

    def outer(t, p, u):
        return ops.nesterov_update(t, p, u, lr=0.7, momentum=0.9, block=64)

    ro = single(outer, t, p, u)
    out["outer_update"] = {
        "bitwise": bitwise(sharded(outer, t, p, u), ro),
        "vs_ref": close(ro, single(
            lambda t, p, u: ref.nesterov_update_ref(t, p, u, lr=0.7, momentum=0.9),
            t, p, u)),
    }

    # -- paged decode over a ragged page table ------------------------------
    pool, ps = 16, 8
    qp = jax.random.normal(k1, (4, 4, 16), jnp.float32)
    kp = jax.random.normal(k2, (pool, ps, 2, 16), jnp.float32)
    vp = jax.random.normal(k3, (pool, ps, 2, 16), jnp.float32)
    tbl = jnp.array([[1, 2, 0], [3, 0, 0], [4, 5, 6], [7, 0, 0]], jnp.int32)
    lens = jnp.array([12, 5, 22, 8], jnp.int32)

    def paged(q, kp, vp, tbl, lens):
        return paged_decode_attention(q, kp, vp, tbl, lens, impl="pallas")

    rp = single(paged, qp, kp, vp, tbl, lens)
    out["paged_decode"] = {
        "bitwise": bitwise(sharded(paged, qp, kp, vp, tbl, lens), rp),
        "vs_ref": close(rp, single(ref.paged_attention_ref,
                                   qp, kp, vp, tbl, lens)),
    }
    return out


if __name__ == "__main__":
    print(json.dumps(main()))
