import jax
import pytest

# Tests run on the single CPU device (the dry-run's 512-device world is
# exercised via tests/test_dryrun_small.py with a small forced device count
# in a subprocess, never here — see the brief).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
