"""CI workflow dry parse: the sharded fast tier must cover every non-slow
test file exactly once, and the job commands must stay consistent with the
repo's test layout (the 'equivalent dry parse' of `act`).

A test file is *slow-only* when every test in it carries
``@pytest.mark.slow`` (detected by AST, so the classification can't rot);
those files belong to the gated slow job, all others to exactly one fast
shard. Adding a test file without slotting it into a shard fails here.
"""
import ast
import glob
import os

import pytest

yaml = pytest.importorskip("yaml", reason="workflow parse needs PyYAML")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
WORKFLOW = os.path.join(REPO, ".github", "workflows", "ci.yml")


@pytest.fixture(scope="module")
def workflow() -> dict:
    assert os.path.exists(WORKFLOW), ".github/workflows/ci.yml is missing"
    with open(WORKFLOW) as f:
        wf = yaml.safe_load(f)
    assert isinstance(wf, dict) and "jobs" in wf, "workflow must define jobs"
    return wf


def _test_files() -> list[str]:
    return sorted(os.path.relpath(p, REPO)
                  for p in glob.glob(os.path.join(REPO, "tests", "test_*.py")))


def _is_slow_only(path: str) -> bool:
    """True when every test function in the file is @pytest.mark.slow."""
    tree = ast.parse(open(os.path.join(REPO, path)).read())
    tests = [n for n in ast.walk(tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
             and n.name.startswith("test_")]
    if not tests:
        return False

    def is_slow(fn) -> bool:
        return any("slow" in ast.dump(d) for d in fn.decorator_list)

    return all(is_slow(fn) for fn in tests)


def _fast_shards(workflow) -> list[dict]:
    fast = workflow["jobs"]["fast-tests"]
    shards = fast["strategy"]["matrix"]["include"]
    assert len(shards) >= 3, "fast tier must shard across >= 3 parallel jobs"
    return shards


def test_workflow_has_required_jobs(workflow):
    jobs = workflow["jobs"]
    for name in ("lint", "fast-tests", "smoke", "slow-tests",
                 "bench-regression"):
        assert name in jobs, f"CI must define the {name} job"


def test_concurrency_cancels_superseded_pr_runs(workflow):
    """Force-pushing a PR branch must cancel the superseded run; pushes to
    main (and scheduled runs) must always complete for bisectability."""
    conc = workflow.get("concurrency")
    assert conc, "workflow must define a concurrency group"
    assert "github.ref" in conc["group"]
    assert "pull_request" in str(conc["cancel-in-progress"])


def test_every_job_has_a_timeout(workflow):
    for name, job in workflow["jobs"].items():
        assert "timeout-minutes" in job, f"{name} job has no timeout-minutes"


def test_single_dispatch_smoke_pins_dispatch_count(workflow):
    """The smoke tier must run a reduced whole-run as ONE device dispatch and
    grep the driver telemetry for it — with a checkpoint cadence that does
    NOT divide the run, so the in-program io_callback path is what's pinned."""
    cmds = " ".join(s.get("run", "") for s in workflow["jobs"]["smoke"]["steps"])
    assert "--rounds-per-dispatch auto" in cmds
    assert "--checkpoint-in-program" in cmds
    assert "dispatches=1" in cmds, "smoke must assert the dispatch count"


def test_kill_resume_smoke_drills_crash_recovery(workflow):
    """The smoke tier must SIGKILL the pinned reduced muon run mid-run,
    resume with --resume auto, and grep that the resumed run reports the
    resume AND converges to the same pinned loss as the uninterrupted
    reference — the crash-safety keystone, exercised on every PR."""
    cmds = " ".join(s.get("run", "") for s in workflow["jobs"]["smoke"]["steps"])
    assert "--inject-kill-round" in cmds, "smoke must SIGKILL a run mid-way"
    assert "--resume auto" in cmds, "smoke must resume the killed run"
    assert "resume telemetry: resumed_from=" in cmds, (
        "smoke must grep the resume telemetry")
    assert "final smoothed eval loss: 6.2911" in cmds, (
        "resumed run must be pinned to the uninterrupted muon reference")
    # the single-dispatch step asserts the round-stamped checkpoint names
    assert "ckpt_4.npz" in cmds and "LATEST" in cmds


def test_bench_regression_job_runs_gate_and_uploads_artifacts(workflow):
    job = workflow["jobs"]["bench-regression"]
    assert "if" in job, "bench tier must be schedule/label/dispatch gated"
    cmds = [s.get("run", "") for s in job["steps"]]
    run_cmd = next(c for c in cmds if "benchmarks.run" in c)
    assert "--json" in run_cmd, "bench run must emit the JSON artifacts"
    assert any("benchmarks.check_regression" in c for c in cmds), (
        "bench tier must diff against the committed baseline")
    uploads = [s for s in job["steps"]
               if "upload-artifact" in str(s.get("uses", ""))]
    assert uploads and "BENCH_" in uploads[0]["with"]["path"]


def _check_regression_module():
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "check_regression",
        os.path.join(REPO, "benchmarks", "check_regression.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_bench_baseline_in_sync_with_target_list(workflow):
    """The committed baseline must cover exactly the pinned REGRESSION_TARGETS,
    and the CI job's --only list must match — a target added to one place but
    not the others fails here, not silently in the gated tier."""
    import json

    mod = _check_regression_module()
    targets = set(mod.REGRESSION_TARGETS)
    with open(os.path.join(REPO, "benchmarks", "baseline.json")) as f:
        base = json.load(f)
    assert set(base["targets"]) == targets, (
        f"baseline.json targets {sorted(base['targets'])} != pinned "
        f"{sorted(targets)} (regenerate with benchmarks.check_regression "
        f"--update)")
    for target, rows in base["targets"].items():
        assert rows, f"baseline target {target} has no rows"
        for name, row in rows.items():
            assert name.split("/", 1)[0] in (target, "kernel"), name
            assert "value" in row and "derived" in row
    run_cmd = next(s["run"] for s in
                   workflow["jobs"]["bench-regression"]["steps"]
                   if "benchmarks.run" in s.get("run", ""))
    only = next(tok for tok in run_cmd.split() if "," in tok)
    assert set(only.split(",")) == targets, (
        f"CI --only list {only} != pinned REGRESSION_TARGETS")


def test_fast_shards_cover_every_nonslow_file_exactly_once(workflow):
    shards = _fast_shards(workflow)
    listed: list[str] = []
    for shard in shards:
        files = shard["files"].split()
        assert files, f"shard {shard.get('shard')} lists no test files"
        listed.extend(files)
    assert len(listed) == len(set(listed)), (
        f"test files listed in more than one shard: "
        f"{sorted(f for f in listed if listed.count(f) > 1)}")
    nonslow = {f for f in _test_files() if not _is_slow_only(f)}
    assert set(listed) == nonslow, (
        f"fast shards out of sync with tests/: "
        f"missing={sorted(nonslow - set(listed))} "
        f"stale={sorted(set(listed) - nonslow)}")
    for f in listed:
        assert os.path.exists(os.path.join(REPO, f)), f"{f} does not exist"


def test_fast_shard_commands_deselect_slow(workflow):
    steps = workflow["jobs"]["fast-tests"]["steps"]
    cmds = [s.get("run", "") for s in steps]
    test_cmd = next(c for c in cmds if "pytest" in c)
    assert '-m "not slow"' in test_cmd
    assert "PYTHONPATH=src" in test_cmd
    assert "${{ matrix.files }}" in test_cmd


def test_slow_job_is_gated_and_runs_slow_marker(workflow):
    slow = workflow["jobs"]["slow-tests"]
    assert "if" in slow, "slow tier must be schedule/label/dispatch gated"
    test_cmd = next(s["run"] for s in slow["steps"] if "pytest" in s.get("run", ""))
    assert "-m slow" in test_cmd and "PYTHONPATH=src" in test_cmd


def test_lint_job_runs_ruff_check_and_format_gate(workflow):
    cmds = [s.get("run", "") for s in workflow["jobs"]["lint"]["steps"]]
    assert any(c.strip().startswith("ruff check .") for c in cmds)
    assert any("--select E101,W191,W291,W292,W293" in c for c in cmds)


def test_smoke_job_exercises_launch_paths(workflow):
    cmds = " ".join(s.get("run", "") for s in workflow["jobs"]["smoke"]["steps"])
    assert "examples/quickstart.py" in cmds
    assert "repro.launch.dryrun" in cmds


def test_jobs_pip_cache_the_jax_install(workflow):
    """Every job must restore the pip cache keyed on requirements-ci.txt."""
    for name, job in workflow["jobs"].items():
        setups = [s for s in job["steps"]
                  if "setup-python" in str(s.get("uses", ""))]
        assert setups, f"{name} job does not set up python"
        with_ = setups[0].get("with", {})
        assert with_.get("cache") == "pip", f"{name} job must pip-cache"
        assert with_.get("cache-dependency-path") == "requirements-ci.txt"


def test_slow_only_classification_matches_known_files():
    """The AST classifier agrees with the repo's current layout (guards the
    classifier itself against rot)."""
    slow_only = {f for f in _test_files() if _is_slow_only(f)}
    assert {"tests/test_parity.py", "tests/test_system.py",
            "tests/test_dryrun_small.py"} <= slow_only
    assert "tests/test_engine.py" not in slow_only
    assert "tests/test_wire.py" not in slow_only
