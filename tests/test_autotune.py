"""Kernel autotune tables: key hashing, lookup/fallback routing, and the
bitwise-inertness contract of the committed entries on the parity path."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.autotune import (
    DEFAULT_TABLE_PATH,
    AutotuneTable,
    autotune_key,
    autotune_scope,
    quantize_block_rows,
    tuned_model_config,
)
from repro.models.common import ModelConfig


def test_autotune_key_is_stable_and_distinct():
    k = autotune_key("quantize", (512, 256, 4), "float32", "cpu")
    assert k == "quantize/512x256x4/float32/cpu"
    # every component participates in the key
    assert autotune_key("ns", (512, 256, 4), "float32", "cpu") != k
    assert autotune_key("quantize", (512, 256, 8), "float32", "cpu") != k
    assert autotune_key("quantize", (512, 256, 4), "bfloat16", "cpu") != k
    assert autotune_key("quantize", (512, 256, 4), "float32", "tpu") != k
    # numpy ints hash like python ints
    assert autotune_key("quantize", tuple(np.int64([512, 256, 4])),
                        "float32", "cpu") == k


def test_table_lookup_hit_miss_and_record(tmp_path):
    t = AutotuneTable()
    t.record("quantize", (64, 32, 4), "float32", "cpu", {"block_rows": 16},
             {"speedup": 2.0})
    assert t.lookup("quantize", (64, 32, 4), "float32", "cpu") == {"block_rows": 16}
    assert t.lookup("quantize", (64, 33, 4), "float32", "cpu") is None
    # save/load round-trips
    path = str(tmp_path / "table.json")
    t.save(path)
    t2 = AutotuneTable.load(path)
    assert t2.entries == t.entries


def test_scope_routes_lookups_and_disable_falls_back(tmp_path):
    path = str(tmp_path / "t.json")
    t = AutotuneTable(path=path)
    t.record("quantize", (8, 4, 4), "float32", jax.default_backend(),
             {"block_rows": 2})
    t.save()
    with autotune_scope(enabled=True, table_path=path):
        assert quantize_block_rows(8, 4, 4, "float32") == 2
        assert quantize_block_rows(9, 4, 4, "float32") is None  # miss
    with autotune_scope(enabled=False):
        assert quantize_block_rows(8, 4, 4, "float32") is None  # off


def test_tuned_model_config_applies_only_known_knobs(tmp_path):
    cfg = ModelConfig(n_heads=4, n_kv_heads=2, head_dim=16, max_seq_len=128,
                      dtype="float32")
    path = str(tmp_path / "t.json")
    t = AutotuneTable(path=path)
    t.record("attention", (128, 4, 2, 16), "float32", jax.default_backend(),
             {"attn_block_q": 64, "attn_block_kv": 32, "junk_knob": 7})
    t.save()
    with autotune_scope(enabled=True, table_path=path):
        tuned = tuned_model_config(cfg, 128)
        assert tuned.attn_block_q == 64 and tuned.attn_block_kv == 32
        assert tuned.blockwise_threshold == cfg.blockwise_threshold
        # an unrelated key in the entry must not reach ModelConfig.replace
        assert not hasattr(tuned, "junk_knob")
        # a shape miss returns the config untouched
        assert tuned_model_config(cfg, 256) is cfg
    with autotune_scope(enabled=False):
        assert tuned_model_config(cfg, 128) is cfg


def test_committed_table_is_wellformed():
    """The committed JSON parses, and every entry carries a config plus the
    sweep's bitwise-verification evidence."""
    with open(DEFAULT_TABLE_PATH) as f:
        entries = json.load(f)
    assert entries, "committed autotune table is empty"
    for key, ent in entries.items():
        kernel = key.split("/", 1)[0]
        assert kernel in ("attention", "quantize", "ns"), key
        assert "config" in ent and ent["config"], key
        assert ent["evidence"].get("verified_bitwise") is True, (
            f"{key}: committed without bitwise verification")


@pytest.mark.parametrize("shape", [(512, 256), (1024, 512)])
def test_tuned_quantize_bitwise_inert_vs_default(shape):
    """The table's block_rows must reproduce the default tiling bit for bit
    on the wire shapes the committed table covers (the parity contract)."""
    x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
    with autotune_scope(enabled=False):
        ref = ops.quantize_rowwise(x, bits=4)  # block_rows falls back to 8
    with autotune_scope(enabled=True):
        tuned = ops.quantize_rowwise(x, bits=4)
    for a, b in zip(ref, tuned):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tuned_ns_bitwise_inert_vs_default():
    g = jax.random.normal(jax.random.PRNGKey(1), (256, 256), jnp.float32)
    with autotune_scope(enabled=False):
        ref = ops.ns_orthogonalize(g)
    with autotune_scope(enabled=True):
        tuned = ops.ns_orthogonalize(g)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(tuned))


def test_tuned_attention_bitwise_inert_on_parity_shape():
    """Reduced smollm's attention shape (the parity path) must produce the
    identical attend() output with the committed table on and off."""
    from repro.configs import get_config, reduce_config
    from repro.models.attention import attend, init_attention

    cfg = reduce_config(get_config("smollm-135m")).replace(max_seq_len=128)
    S = 128
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, S, cfg.d_model),
                          jnp.float32)
    pos = jnp.arange(S)

    def run():
        c = tuned_model_config(cfg, S)
        return np.asarray(jax.jit(lambda p, x: attend(p, c, x, pos))(p, x))

    with autotune_scope(enabled=False):
        ref = run()
    with autotune_scope(enabled=True):
        tuned = run()
    np.testing.assert_array_equal(ref, tuned)


def test_sweep_rejects_non_inert_candidates():
    """The sweep's bitwise gate: a candidate that changes the output must
    never win, whatever its timing."""
    from repro.kernels.autotune import _sweep

    calls = []

    def run(knob):
        calls.append(knob)
        # knob 1 is the default; knob 2 is 'faster' but changes the result
        return jnp.array([1.0 if knob == 1 else 2.0])

    best, ev = _sweep(run, {"knob": 1}, [{"knob": 2}], reps=1)
    assert best == {"knob": 1}
    assert ev["rejected_not_bitwise"] == 1
    assert ev["verified_bitwise"] is True
