"""Per-architecture smoke tests (reduced configs) + decode/forward
consistency across every family — the strongest correctness check we have
(it validates KV caches, ring buffers, chunked SSD vs recurrence, cross-attn
caches, and MoE dispatch all at once)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduce_config
from repro.models import build_model
from repro.optim import OptimizerConfig, muon


def _ctx(cfg, batch):
    if cfg.arch_type == "audio":
        return jax.random.normal(jax.random.PRNGKey(5), (batch, cfg.n_audio_frames, cfg.d_model))
    if cfg.arch_type == "vlm":
        return jax.random.normal(jax.random.PRNGKey(5), (batch, cfg.n_image_tokens, cfg.d_model))
    return None


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced variant: forward + one Muon train step, shapes + finiteness."""
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    ctx = _ctx(cfg, B)
    if ctx is not None:
        batch["context"] = ctx

    logits, _ = model.forward(params, batch["tokens"], context=ctx)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
    assert np.isfinite(float(loss))
    opt = muon(OptimizerConfig(lr=1e-3))
    st = opt.init(params)
    new_params, _ = opt.step(params, grads, st)
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_decode_matches_forward(arch):
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    ctx = _ctx(cfg, B)
    logits_full, _ = model.forward(params, toks, context=ctx)

    cache = model.init_cache(params, B, S)
    if ctx is not None:
        # conditions cross-attn families; no-op passthrough for the rest
        cache = model.fill_context(params, cache, ctx)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t], jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    # sliding-window archs only match within the window
    lo = max(0, S - cfg.sliding_window) if cfg.sliding_window else 0
    np.testing.assert_allclose(np.asarray(dec[:, lo:]), np.asarray(logits_full[:, lo:]),
                               rtol=5e-3, atol=5e-3)


def test_blockwise_attention_exact():
    import repro.models.attention as A
    from repro.models.common import ModelConfig

    cfg = ModelConfig(n_heads=4, n_kv_heads=2, d_model=64, head_dim=16,
                      dtype="float32", qk_norm=False)
    B, S, H, KV, hd = 2, 1024, 4, 2, 16
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (B, S, H, hd))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, KV, hd))
    i = jnp.arange(S)
    scores = A._gqa_scores(q, kk).astype(jnp.float32)
    mask = i[:, None] >= i[None, :]
    probs = jax.nn.softmax(jnp.where(mask[None, None, None], scores, A.NEG_INF), -1)
    exact = A._gqa_out(probs, v).reshape(B, S, H, hd)
    blocked = A._blockwise_attention(cfg, q, kk, v, causal=True, block_q=128, block_kv=256)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(exact), rtol=2e-5, atol=2e-5)


def test_mamba_chunk_invariance():
    """Chunked SSD must be invariant to the chunk size (same math)."""
    from repro.models.common import ModelConfig
    from repro.models.ssm import init_mamba, mamba_forward

    base = ModelConfig(arch_type="ssm", d_model=32, ssm_state=8, ssm_head_dim=8,
                       ssm_chunk=4, vocab=16, dtype="float32")
    p = init_mamba(jax.random.PRNGKey(0), base)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    y4 = mamba_forward(p, base, x)
    y8 = mamba_forward(p, base.replace(ssm_chunk=8), x)
    y16 = mamba_forward(p, base.replace(ssm_chunk=16), x)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y8), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y16), rtol=1e-4, atol=1e-5)


def test_fused_ce_equals_plain():
    from repro.models import ModelConfig, build_model

    cfg = ModelConfig(arch_type="dense", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab=128, remat=False, dtype="float32")
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, 128)
    b = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    lf, _ = m.loss(p, b, fused=True)
    lp, _ = m.loss(p, b, fused=False)
    assert abs(float(lf) - float(lp)) < 1e-5
    gf = jax.grad(lambda p: m.loss(p, b, fused=True)[0])(p)
    gp = jax.grad(lambda p: m.loss(p, b, fused=False)[0])(p)
    errs = jax.tree.map(lambda a, c: float(jnp.max(jnp.abs(a - c))), gf, gp)
    assert max(jax.tree.leaves(errs)) < 1e-5


def test_moe_capacity_overflow_drops_gracefully():
    """With capacity_factor ~0, most tokens drop but output stays finite and
    shared experts still contribute."""
    from repro.models import ModelConfig
    from repro.models.mlp import init_moe, moe

    cfg = ModelConfig(arch_type="moe", d_model=16, d_ff=32, n_experts=4,
                      experts_per_token=2, n_shared_experts=1, capacity_factor=0.01,
                      moe_groups=2, dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), cfg, n_layers=None)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    out, aux = moe(p, cfg, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert np.isfinite(float(aux))
