"""TrainEngine invariants: DP degeneracy, streaming parity, donation,
no-retrace, TrainState pytree/mapping behaviour, checkpoint roundtrip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import DiLoCoConfig, diloco_round, dp_config, dp_init, dp_step, make_optimizer
from repro.data import DataConfig, MarkovStream, batches_for_round
from repro.engine import TrainEngine, TrainState, dp_engine, run_rounds
from repro.models import ModelConfig, build_model
from repro.optim import OptimizerConfig

CFG = ModelConfig(arch_type="dense", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                  d_ff=64, vocab=64, remat=False, dtype="float32", qk_norm=True)
ICFG = OptimizerConfig(lr=1e-2, weight_decay=0.0)


def _stream(n_workers, bs=2, s=16, seed=3):
    return MarkovStream(DataConfig(vocab=CFG.vocab, seq_len=s, batch_per_worker=bs,
                                   n_workers=n_workers, seed=seed))


# ---------------------------------------------------------------------------
# DP degeneracy: the (K=1, H=1, no-outer) engine IS the plain inner optimizer
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("inner", ["adamw", "muon"])
def test_dp_engine_equals_dp_step(inner):
    model = build_model(CFG)
    engine = dp_engine(model, inner, ICFG)
    state = engine.init(jax.random.PRNGKey(0))
    dp_state, opt = dp_init(model, inner, ICFG, jax.random.PRNGKey(0))
    stream = _stream(1)
    for r in range(3):
        batches = batches_for_round(stream, r, 1)
        state, _ = engine.step(state, batches)
        dp_state, _ = dp_step(model, opt, dp_state,
                              jax.tree.map(lambda x: x[0, 0], batches))
    a = state["outer_params"]["layers"]["mlp"]["w_in"]
    b = dp_state["params"]["layers"]["mlp"]["w_in"]
    # both sides share inner_step; only compilation layout differs. Muon's
    # bf16 Newton-Schulz amplifies ~1e-7 rounding, so its tolerance is looser.
    kw = dict(rtol=2e-2, atol=1e-3) if inner == "muon" else dict(rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **kw)


def test_dp_config_shape():
    dcfg = dp_config("muon")
    assert dcfg.n_workers == 1 and dcfg.sync_interval == 1
    assert not dcfg.outer_enabled and dcfg.is_muloco


# ---------------------------------------------------------------------------
# Streaming: J>1 matches J==1 signature and loss trajectory
# ---------------------------------------------------------------------------


def test_streaming_round_signature_matches_dense():
    model = build_model(CFG)
    infos = {}
    for J in (1, 2):
        dcfg = DiLoCoConfig(n_workers=2, sync_interval=4, inner_name="muon",
                            streaming_partitions=J)
        engine = TrainEngine(model, dcfg, ICFG)
        state = engine.init(jax.random.PRNGKey(0))
        _, info = engine.step(state, batches_for_round(_stream(2), 0, 4))
        infos[J] = info
    assert sorted(infos[1]) == sorted(infos[2]) == ["loss", "psi"]
    assert infos[1]["loss"].shape == infos[2]["loss"].shape == (4,)
    assert (jax.tree.structure(infos[1]["psi"])
            == jax.tree.structure(infos[2]["psi"]))


def test_streaming_j2_tracks_j1_loss_trajectory():
    model = build_model(CFG)
    traj = {}
    for J in (1, 2):
        dcfg = DiLoCoConfig(n_workers=2, sync_interval=4, inner_name="muon",
                            streaming_partitions=J)
        engine = TrainEngine(model, dcfg, ICFG)
        state = engine.init(jax.random.PRNGKey(0))
        losses = []
        for r in range(3):
            state, info = engine.step(state, batches_for_round(_stream(2), r, 4))
            losses.append(float(info["loss"].mean()))
        traj[J] = losses
    # same data, same inner opt: per-round means must track closely
    for a, b in zip(traj[1], traj[2]):
        assert abs(a - b) < 0.15 * a


def test_streaming_requires_divisible_partitions():
    model = build_model(CFG)
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=4, inner_name="muon",
                        streaming_partitions=3)  # 3 does not divide 4
    opt = make_optimizer(dcfg, ICFG)
    engine = TrainEngine(model, dcfg, ICFG)
    state = engine.init(jax.random.PRNGKey(0))
    batches = batches_for_round(_stream(2), 0, 4)
    with pytest.raises(ValueError, match="divide"):
        diloco_round(model, dcfg, opt, state, batches, masks=engine._masks)


def test_streaming_requires_masks():
    model = build_model(CFG)
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=4, inner_name="muon",
                        streaming_partitions=2)
    opt = make_optimizer(dcfg, ICFG)
    state = TrainEngine(model, dcfg, ICFG).init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="masks"):
        diloco_round(model, dcfg, opt, state, batches_for_round(_stream(2), 0, 4),
                     masks=None)


# ---------------------------------------------------------------------------
# Donation + no-retrace
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("inner", ["adamw", "muon_bp", "normuon"])
def test_round_fn_donates_state_and_never_retraces(inner):
    """Every transform-chain inner optimizer lowers through the engine's
    single donated jitted round with no retrace."""
    model = build_model(CFG)
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=2, inner_name=inner)
    icfg = ICFG if inner != "muon_bp" else OptimizerConfig(
        lr=1e-2, weight_decay=0.0, ns_period=2)
    engine = TrainEngine(model, dcfg, icfg)
    state = engine.init(jax.random.PRNGKey(0))
    stream = _stream(2)

    lowered = engine.lower(state, batches_for_round(stream, 0, 2))
    # the TrainState argument is donated: input buffers alias outputs
    assert "tf.aliasing_output" in lowered.as_text()
    assert lowered.compile().memory_analysis().alias_size_in_bytes > 0

    for r in range(3):
        state, _ = engine.step(state, batches_for_round(stream, r, 2))
    # three executions (differing data, same shapes) -> exactly one trace
    assert engine.jitted_round._cache_size() == 1


def test_outer_kernel_round_matches_xla_outer():
    """outer_kernel=True routes the sync through the fused Pallas kernel and
    tracks the pure-XLA outer transform."""
    model = build_model(CFG)
    params = {}
    for kernel in (False, True):
        dcfg = DiLoCoConfig(n_workers=2, sync_interval=2, inner_name="adamw",
                            outer_kernel=kernel)
        engine = TrainEngine(model, dcfg, ICFG)
        state = engine.init(jax.random.PRNGKey(0))
        for r in range(2):
            state, _ = engine.step(state, batches_for_round(_stream(2), r, 2))
        params[kernel] = state["outer_params"]["layers"]["mlp"]["w_in"]
    np.testing.assert_allclose(np.asarray(params[True]), np.asarray(params[False]),
                               rtol=1e-5, atol=1e-6)


def test_batches_for_round_matches_per_step_batches():
    """The single-dispatch stacked generation is bitwise the H per-step
    batches it replaced."""
    stream = _stream(3, bs=2, s=8)
    stacked = batches_for_round(stream, 5, 4)
    for h in range(4):
        per_step = stream.batch(5 * 4 + h)
        for key in ("tokens", "labels"):
            np.testing.assert_array_equal(np.asarray(stacked[key][h]),
                                          np.asarray(per_step[key]))


def test_run_rounds_driver_collects_all_metrics():
    model = build_model(CFG)
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=2, inner_name="adamw")
    engine = TrainEngine(model, dcfg, ICFG)
    state = engine.init(jax.random.PRNGKey(0))
    stream = _stream(2)
    seen = []
    state, history = run_rounds(
        engine, state, lambda r: batches_for_round(stream, r, 2), 5,
        eval_fn=lambda st, r: engine.eval_loss(
            st["outer_params"], jax.tree.map(lambda x: x[0], stream.batch(r))),
        on_round=lambda rec: seen.append(rec["round"]),
    )
    assert seen == [0, 1, 2, 3, 4]
    assert [h["round"] for h in history] == seen
    assert all(np.isfinite(h["train_loss"]) and np.isfinite(h["eval_loss"])
               for h in history)
    assert history[-1]["step"] == 10
    assert int(state["round"]) == 5


# ---------------------------------------------------------------------------
# TrainState: pytree behaviour + dict-era compatibility + checkpointing
# ---------------------------------------------------------------------------


def test_trainstate_is_pytree_with_mapping_access():
    model = build_model(CFG)
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=2, inner_name="adamw")
    state = TrainEngine(model, dcfg, ICFG).init(jax.random.PRNGKey(0))
    assert isinstance(state, TrainState)
    # mapping-style access (legacy call sites)
    assert state["round"].dtype == jnp.int32
    assert "ef" not in state and state.ef is None
    assert set(state.keys()) == {"outer_params", "outer_opt", "worker_params",
                                 "inner_state", "round"}
    # flatten/unflatten roundtrip preserves structure and values
    leaves, treedef = jax.tree.flatten(state)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert isinstance(rebuilt, TrainState)
    np.testing.assert_array_equal(
        np.asarray(rebuilt["worker_params"]["embed"]),
        np.asarray(state["worker_params"]["embed"]))
    # setitem (analysis helpers mutate states in place)
    state["outer_params"] = jax.tree.map(jnp.zeros_like, state["outer_params"])
    assert float(jnp.abs(state.outer_params["embed"]).max()) == 0.0


def test_trainstate_checkpoint_roundtrip(tmp_path):
    model = build_model(CFG)
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=2, inner_name="muon")
    engine = TrainEngine(model, dcfg, ICFG)
    state = engine.init(jax.random.PRNGKey(0))
    state, _ = engine.step(state, batches_for_round(_stream(2), 0, 2))
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, state, step=7)
    template = engine.init(jax.random.PRNGKey(1))
    restored, step = load_checkpoint(path, template)
    assert step == 7
    assert isinstance(restored, TrainState)
    np.testing.assert_allclose(
        np.asarray(restored["outer_params"]["embed"]),
        np.asarray(state["outer_params"]["embed"]), rtol=1e-6)
