"""TrainEngine invariants: DP degeneracy, superstep bit-parity, streaming
parity, donation, no-retrace, TrainState pytree/mapping behaviour,
checkpoint roundtrip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import DiLoCoConfig, diloco_round, dp_config, dp_init, dp_step, make_optimizer
from repro.data import DataConfig, MarkovStream, batches_for_round, batches_for_span
from repro.engine import (
    TrainEngine,
    TrainState,
    dp_engine,
    effective_rounds_per_dispatch,
    run_rounds,
)
from repro.models import ModelConfig, build_model
from repro.optim import OptimizerConfig

CFG = ModelConfig(arch_type="dense", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                  d_ff=64, vocab=64, remat=False, dtype="float32", qk_norm=True)
ICFG = OptimizerConfig(lr=1e-2, weight_decay=0.0)


def _stream(n_workers, bs=2, s=16, seed=3):
    return MarkovStream(DataConfig(vocab=CFG.vocab, seq_len=s, batch_per_worker=bs,
                                   n_workers=n_workers, seed=seed))


# ---------------------------------------------------------------------------
# DP degeneracy: the (K=1, H=1, no-outer) engine IS the plain inner optimizer
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("inner", ["adamw", "muon"])
def test_dp_engine_equals_dp_step(inner):
    model = build_model(CFG)
    engine = dp_engine(model, inner, ICFG)
    state = engine.init(jax.random.PRNGKey(0))
    dp_state, opt = dp_init(model, inner, ICFG, jax.random.PRNGKey(0))
    stream = _stream(1)
    for r in range(3):
        batches = batches_for_round(stream, r, 1)
        state, _ = engine.step(state, batches)
        dp_state, _ = dp_step(model, opt, dp_state,
                              jax.tree.map(lambda x: x[0, 0], batches))
    a = state["outer_params"]["layers"]["mlp"]["w_in"]
    b = dp_state["params"]["layers"]["mlp"]["w_in"]
    # both sides share inner_step; only compilation layout differs. Muon's
    # bf16 Newton-Schulz amplifies ~1e-7 rounding, so its tolerance is looser.
    kw = dict(rtol=2e-2, atol=1e-3) if inner == "muon" else dict(rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **kw)


def test_dp_config_shape():
    dcfg = dp_config("muon")
    assert dcfg.n_workers == 1 and dcfg.sync_interval == 1
    assert not dcfg.outer_enabled and dcfg.is_muloco


# ---------------------------------------------------------------------------
# Superstep: R rounds per dispatch == R sequential rounds, bit for bit
# ---------------------------------------------------------------------------


def _fresh(inner="muon", H=4, K=2):
    model = build_model(CFG)
    dcfg = DiLoCoConfig(n_workers=K, sync_interval=H, inner_name=inner)
    engine = TrainEngine(model, dcfg, ICFG)
    return engine, engine.init(jax.random.PRNGKey(0))


@pytest.mark.parametrize("inner", ["adamw", "muon"])
def test_superstep_matches_sequential_rounds_bitwise(inner):
    """One R=4 dispatch replays 4 sequential engine.step rounds exactly."""
    H, R = 4, 4
    e1, s1 = _fresh(inner, H)
    losses = []
    for r in range(R):
        s1, info = e1.step(s1, batches_for_round(_stream(2), r, H))
        losses.append(np.asarray(info["loss"]))

    e2, s2 = _fresh(inner, H)
    s2, out = e2.superstep(s2, batches_for_span(_stream(2), 0, H, R))
    assert out["loss"].shape == (R, H)
    np.testing.assert_array_equal(np.asarray(out["loss"]), np.stack(losses))
    np.testing.assert_array_equal(
        np.asarray(s2["outer_params"]["layers"]["mlp"]["w_in"]),
        np.asarray(s1["outer_params"]["layers"]["mlp"]["w_in"]))
    assert int(s2["round"]) == R  # counter advanced on device, inside the scan


def test_superstep_folded_eval_matches_separate_jit():
    """The [R] eval buffer equals per-round engine.eval_loss on the synced
    params — eval rides inside the superstep program without changing it."""
    H, R = 4, 3
    ev_stream = MarkovStream(DataConfig(vocab=CFG.vocab, seq_len=16,
                                        batch_per_worker=2, n_workers=1, seed=99))
    e1, s1 = _fresh("muon", H)
    separate = []
    for r in range(R):
        s1, _ = e1.step(s1, batches_for_round(_stream(2), r, H))
        separate.append(float(e1.eval_loss(
            s1["outer_params"], jax.tree.map(lambda x: x[0], ev_stream.batch(r)))))

    e2, s2 = _fresh("muon", H)
    eb = jax.tree.map(lambda x: x[:, 0], ev_stream.batch_stack(0, R))
    s2, out = e2.superstep(s2, batches_for_span(_stream(2), 0, H, R), eb)
    assert out["loss"].shape == (R, H) and out["eval_loss"].shape == (R,)
    np.testing.assert_array_equal(np.asarray(out["eval_loss"]),
                                  np.asarray(separate, np.float32))


def test_batches_for_span_matches_stacked_rounds():
    stream = _stream(3, bs=2, s=8)
    span = batches_for_span(stream, 2, 4, 3)
    for i in range(3):
        per_round = batches_for_round(_stream(3, bs=2, s=8), 2 + i, 4)
        for key in ("tokens", "labels"):
            np.testing.assert_array_equal(np.asarray(span[key][i]),
                                          np.asarray(per_round[key]))


def test_effective_rounds_per_dispatch_clamps():
    assert effective_rounds_per_dispatch(1, 100) == 1
    assert effective_rounds_per_dispatch(4, 8) == 4
    assert effective_rounds_per_dispatch(4, 6) == 2          # divides the run
    assert effective_rounds_per_dispatch(4, 8, 6) == 2       # and the cadence
    assert effective_rounds_per_dispatch(5, 25, 10) == 5
    assert effective_rounds_per_dispatch(3, 8, 4) == 1       # nothing fits
    assert effective_rounds_per_dispatch(0, 8) == 1
    # resumed off-cadence: boundaries start + k*R must still hit every
    # absolute cadence point (rounds 8, 16 with start=6 -> R=2, not 4)
    assert effective_rounds_per_dispatch(4, 16, 8, start=6) == 2
    assert effective_rounds_per_dispatch(8, 16, 8, start=4) == 4
    assert effective_rounds_per_dispatch(4, 16, 8, start=8) == 4  # aligned start
    assert effective_rounds_per_dispatch(4, 16, 8) == 4           # no resume


def test_run_rounds_checkpoints_after_offset_resume():
    """A resume whose start round is off the checkpoint cadence must still
    checkpoint at every absolute cadence point (regression: the superstep
    boundary condition used to skip them all)."""
    engine, state = _fresh("adamw", H=2)
    stream = _stream(2)
    saves = []
    run_rounds(
        engine, state, lambda r: batches_for_round(stream, r, 2), 10,
        start=2, rounds_per_dispatch=4,
        span_batches_for=lambda r0, n: batches_for_span(stream, r0, 2, n),
        on_state=lambda r, st: saves.append(r),
        on_state_every=4)
    # cadence points after start=2: rounds-completed 4 and 8 -> r = 3, 7
    assert saves == [3, 7]


def test_run_rounds_superstep_history_matches_r1():
    """run_rounds at R=2 emits the identical per-round records as R=1."""
    histories = {}
    for R in (1, 2):
        engine, state = _fresh("adamw", H=2)
        stream = _stream(2)
        _, histories[R] = run_rounds(
            engine, state, lambda r: batches_for_round(stream, r, 2), 4,
            rounds_per_dispatch=R,
            span_batches_for=lambda r0, n: batches_for_span(stream, r0, 2, n))
    assert [h["round"] for h in histories[2]] == [0, 1, 2, 3]
    for a, b in zip(histories[1], histories[2]):
        assert a == b  # floats drained from the same device arithmetic


def test_run_rounds_superstep_checkpoint_cadence():
    """on_state fires at every cadence boundary; requested R=4 is clamped to
    divide checkpoint_every=2, and the CSV (on_round) never lags a save."""
    engine, state = _fresh("adamw", H=2)
    stream = _stream(2)
    saves, rounds_seen = [], []
    run_rounds(
        engine, state, lambda r: batches_for_round(stream, r, 2), 8,
        rounds_per_dispatch=4,
        span_batches_for=lambda r0, n: batches_for_span(stream, r0, 2, n),
        on_round=lambda rec: rounds_seen.append(rec["round"]),
        on_state=lambda r, st: saves.append((r, len(rounds_seen))),
        on_state_every=2)
    assert [r for r, _ in saves] == [1, 3, 5, 7]
    # at each save, all rounds up to it were already drained to on_round
    assert all(n_drained >= r + 1 for r, n_drained in saves)
    assert rounds_seen == list(range(8))


def test_run_rounds_host_eval_fn_pins_r1():
    """The legacy host-side eval_fn needs per-round state, so a requested
    R>1 falls back to single-round dispatch — and still evaluates every
    round."""
    engine, state = _fresh("adamw", H=2)
    stream = _stream(2)
    _, history = run_rounds(
        engine, state, lambda r: batches_for_round(stream, r, 2), 4,
        rounds_per_dispatch=4,
        eval_fn=lambda st, r: engine.eval_loss(
            st["outer_params"], jax.tree.map(lambda x: x[0], stream.batch(r))))
    assert [h["round"] for h in history] == [0, 1, 2, 3]
    assert all(np.isfinite(h["eval_loss"]) for h in history)


# ---------------------------------------------------------------------------
# Streaming: J>1 matches J==1 signature and loss trajectory
# ---------------------------------------------------------------------------


def test_streaming_round_signature_matches_dense():
    model = build_model(CFG)
    infos = {}
    for J in (1, 2):
        dcfg = DiLoCoConfig(n_workers=2, sync_interval=4, inner_name="muon",
                            streaming_partitions=J)
        engine = TrainEngine(model, dcfg, ICFG)
        state = engine.init(jax.random.PRNGKey(0))
        _, info = engine.step(state, batches_for_round(_stream(2), 0, 4))
        infos[J] = info
    assert sorted(infos[1]) == sorted(infos[2]) == [
        "active_workers", "comm_bytes", "loss", "psi", "staleness"]
    assert infos[1]["loss"].shape == infos[2]["loss"].shape == (4,)
    # streaming's J segment syncs each ship their partition's share: the
    # measured per-round wire bytes must equal the dense single sync
    assert float(infos[1]["comm_bytes"]) == float(infos[2]["comm_bytes"]) > 0
    assert (jax.tree.structure(infos[1]["psi"])
            == jax.tree.structure(infos[2]["psi"]))


def test_streaming_j2_tracks_j1_loss_trajectory():
    model = build_model(CFG)
    traj = {}
    for J in (1, 2):
        dcfg = DiLoCoConfig(n_workers=2, sync_interval=4, inner_name="muon",
                            streaming_partitions=J)
        engine = TrainEngine(model, dcfg, ICFG)
        state = engine.init(jax.random.PRNGKey(0))
        losses = []
        for r in range(3):
            state, info = engine.step(state, batches_for_round(_stream(2), r, 4))
            losses.append(float(info["loss"].mean()))
        traj[J] = losses
    # same data, same inner opt: per-round means must track closely
    for a, b in zip(traj[1], traj[2]):
        assert abs(a - b) < 0.15 * a


def test_streaming_requires_divisible_partitions():
    model = build_model(CFG)
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=4, inner_name="muon",
                        streaming_partitions=3)  # 3 does not divide 4
    opt = make_optimizer(dcfg, ICFG)
    engine = TrainEngine(model, dcfg, ICFG)
    state = engine.init(jax.random.PRNGKey(0))
    batches = batches_for_round(_stream(2), 0, 4)
    with pytest.raises(ValueError, match="divide"):
        diloco_round(model, dcfg, opt, state, batches, masks=engine._masks)


def test_streaming_requires_masks():
    model = build_model(CFG)
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=4, inner_name="muon",
                        streaming_partitions=2)
    opt = make_optimizer(dcfg, ICFG)
    state = TrainEngine(model, dcfg, ICFG).init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="masks"):
        diloco_round(model, dcfg, opt, state, batches_for_round(_stream(2), 0, 4),
                     masks=None)


# ---------------------------------------------------------------------------
# Donation + no-retrace
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("inner", ["adamw", "muon_bp", "normuon"])
def test_round_fn_donates_state_and_never_retraces(inner):
    """Every transform-chain inner optimizer lowers through the engine's
    single donated jitted round with no retrace."""
    model = build_model(CFG)
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=2, inner_name=inner)
    icfg = ICFG if inner != "muon_bp" else OptimizerConfig(
        lr=1e-2, weight_decay=0.0, ns_period=2)
    engine = TrainEngine(model, dcfg, icfg)
    state = engine.init(jax.random.PRNGKey(0))
    stream = _stream(2)

    lowered = engine.lower(state, batches_for_round(stream, 0, 2))
    # the TrainState argument is donated: input buffers alias outputs
    assert "tf.aliasing_output" in lowered.as_text()
    assert lowered.compile().memory_analysis().alias_size_in_bytes > 0

    for r in range(3):
        state, _ = engine.step(state, batches_for_round(stream, r, 2))
    # three executions (differing data, same shapes) -> exactly one trace
    assert engine.jitted_round._cache_size() == 1


def test_outer_kernel_round_matches_xla_outer():
    """outer_kernel=True routes the sync through the fused Pallas kernel and
    tracks the pure-XLA outer transform."""
    model = build_model(CFG)
    params = {}
    for kernel in (False, True):
        dcfg = DiLoCoConfig(n_workers=2, sync_interval=2, inner_name="adamw",
                            outer_kernel=kernel)
        engine = TrainEngine(model, dcfg, ICFG)
        state = engine.init(jax.random.PRNGKey(0))
        for r in range(2):
            state, _ = engine.step(state, batches_for_round(_stream(2), r, 2))
        params[kernel] = state["outer_params"]["layers"]["mlp"]["w_in"]
    np.testing.assert_allclose(np.asarray(params[True]), np.asarray(params[False]),
                               rtol=1e-5, atol=1e-6)


def test_batches_for_round_matches_per_step_batches():
    """The single-dispatch stacked generation is bitwise the H per-step
    batches it replaced."""
    stream = _stream(3, bs=2, s=8)
    stacked = batches_for_round(stream, 5, 4)
    for h in range(4):
        per_step = stream.batch(5 * 4 + h)
        for key in ("tokens", "labels"):
            np.testing.assert_array_equal(np.asarray(stacked[key][h]),
                                          np.asarray(per_step[key]))


def test_run_rounds_driver_collects_all_metrics():
    model = build_model(CFG)
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=2, inner_name="adamw")
    engine = TrainEngine(model, dcfg, ICFG)
    state = engine.init(jax.random.PRNGKey(0))
    stream = _stream(2)
    seen = []
    state, history = run_rounds(
        engine, state, lambda r: batches_for_round(stream, r, 2), 5,
        eval_fn=lambda st, r: engine.eval_loss(
            st["outer_params"], jax.tree.map(lambda x: x[0], stream.batch(r))),
        on_round=lambda rec: seen.append(rec["round"]),
    )
    assert seen == [0, 1, 2, 3, 4]
    assert [h["round"] for h in history] == seen
    assert all(np.isfinite(h["train_loss"]) and np.isfinite(h["eval_loss"])
               for h in history)
    assert history[-1]["step"] == 10
    assert int(state["round"]) == 5


# ---------------------------------------------------------------------------
# TrainState: pytree behaviour + dict-era compatibility + checkpointing
# ---------------------------------------------------------------------------


def test_trainstate_is_pytree_with_mapping_access():
    model = build_model(CFG)
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=2, inner_name="adamw")
    state = TrainEngine(model, dcfg, ICFG).init(jax.random.PRNGKey(0))
    assert isinstance(state, TrainState)
    # mapping-style access (legacy call sites)
    assert state["round"].dtype == jnp.int32
    assert "ef" not in state and state.ef is None
    assert set(state.keys()) == {"outer_params", "outer_opt", "worker_params",
                                 "inner_state", "round"}
    # flatten/unflatten roundtrip preserves structure and values
    leaves, treedef = jax.tree.flatten(state)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert isinstance(rebuilt, TrainState)
    np.testing.assert_array_equal(
        np.asarray(rebuilt["worker_params"]["embed"]),
        np.asarray(state["worker_params"]["embed"]))
    # setitem (analysis helpers mutate states in place)
    state["outer_params"] = jax.tree.map(jnp.zeros_like, state["outer_params"])
    assert float(jnp.abs(state.outer_params["embed"]).max()) == 0.0


def test_trainstate_checkpoint_roundtrip(tmp_path):
    model = build_model(CFG)
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=2, inner_name="muon")
    engine = TrainEngine(model, dcfg, ICFG)
    state = engine.init(jax.random.PRNGKey(0))
    state, _ = engine.step(state, batches_for_round(_stream(2), 0, 2))
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, state, step=7)
    template = engine.init(jax.random.PRNGKey(1))
    restored, step = load_checkpoint(path, template)
    assert step == 7
    assert isinstance(restored, TrainState)
    np.testing.assert_allclose(
        np.asarray(restored["outer_params"]["embed"]),
        np.asarray(state["outer_params"]["embed"]), rtol=1e-6)
