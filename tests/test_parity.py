"""Exact-parity guard: the transform-stack optimizers must reproduce the
seed's fixed-seed reference run bit-for-bit at the reported precision.

The reference command (see CHANGES.md PR 1) is

    train.py --arch smollm-135m --reduced --inner {muon,adamw} --workers 2 \
        --sync-interval 4 --rounds 6 --seq-len 64 --batch-per-worker 4 --seed 0

whose final smoothed eval losses are pinned below. Any reassociation of the
optimizer arithmetic (descent order, weight-decay coupling, schedule
placement) shows up here: Muon's bf16 Newton–Schulz chaotically amplifies
even 1-ulp perturbations across the 24 steps.
"""
import pytest

from repro.launch.train import build_parser, train

REFERENCE = {"muon": 6.2911, "adamw": 6.8274}


@pytest.mark.slow
@pytest.mark.parametrize("inner", ["muon", "adamw"])
def test_fixed_seed_reference_losses(inner, tmp_path):
    args = build_parser().parse_args([
        "--arch", "smollm-135m", "--reduced", "--inner", inner,
        "--workers", "2", "--sync-interval", "4", "--rounds", "6",
        "--seq-len", "64", "--batch-per-worker", "4", "--seed", "0",
        "--out", str(tmp_path / inner),
    ])
    result = train(args)
    assert round(result["final_loss"], 4) == REFERENCE[inner]
