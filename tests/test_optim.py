"""Optimizer unit tests: AdamW semantics, Muon labeling/structure, schedules,
Nesterov outer update, memory-complexity claim, and the transform-stack
combinators (chain associativity, partition routing, variant reductions)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    OptimizerConfig,
    adamw,
    chain,
    cosine_schedule,
    identity,
    muon,
    muon_bp,
    muon_label,
    nesterov,
    nesterov_init,
    nesterov_step,
    normuon,
    param_labels,
    partition,
    scale_by_adam,
    stateless,
    trace_momentum,
)
from repro.utils.tree import tree_bytes, tree_leaves_with_paths


def _params():
    return {
        "embed": jnp.ones((32, 16)),
        "layers": {
            "attn": {"wq": jnp.ones((2, 16, 16)), "q_norm_scale": jnp.ones((2, 4))},
            "mlp": {"w_in": jnp.ones((2, 16, 32)), "w_out": jnp.ones((2, 32, 16))},
        },
        "head": jnp.ones((16, 32)),
        "final_norm_scale": jnp.ones((16,)),
    }


def _grads(seed=0):
    p = _params()
    leaves, treedef = jax.tree.flatten(p)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    return jax.tree.unflatten(
        treedef, [jax.random.normal(k, leaf.shape) for k, leaf in zip(keys, leaves)])


def test_param_labels():
    labels = param_labels(_params())
    assert labels["embed"] == "adamw"
    assert labels["head"] == "adamw"
    assert labels["final_norm_scale"] == "adamw"
    assert labels["layers"]["attn"]["wq"] == "muon"
    assert labels["layers"]["attn"]["q_norm_scale"] == "adamw"
    assert labels["layers"]["mlp"]["w_in"] == "muon"


def test_adamw_first_step_is_lr_sized():
    """With bias correction, |first step| ~= lr for any gradient scale."""
    p = {"w": jnp.zeros((4, 4))}
    for gscale in (1e-3, 1.0, 1e3):
        opt = adamw(OptimizerConfig(lr=0.01, weight_decay=0.0))
        st = opt.init(p)
        g = {"w": jnp.full((4, 4), gscale)}
        p2, _ = opt.step(p, g, st)
        np.testing.assert_allclose(np.asarray(p2["w"]), -0.01, rtol=1e-3)


def test_adamw_weight_decay_decoupled():
    p = {"w": jnp.full((2, 2), 10.0)}
    opt = adamw(OptimizerConfig(lr=0.1, weight_decay=0.5))
    st = opt.init(p)
    g = {"w": jnp.zeros((2, 2))}
    p2, _ = opt.step(p, g, st)
    # zero grad: update is pure decay p - lr*wd*p
    np.testing.assert_allclose(np.asarray(p2["w"]), 10.0 - 0.1 * 0.5 * 10.0, rtol=1e-5)


def test_muon_memory_advantage():
    """Paper Tab. 9: Muon holds 3 param copies vs AdamW's 4 (the partitioned
    second moment only exists for the AdamW-labelled leaves)."""
    p = _params()
    st_m = muon(OptimizerConfig()).init(p)
    st_a = adamw(OptimizerConfig()).init(p)
    assert tree_bytes(st_m) < 0.75 * tree_bytes(st_a)


def test_muon_hidden_update_is_orthonormal_scale():
    p = {"w": jnp.zeros((16, 64))}
    opt = muon(OptimizerConfig(lr=0.1, weight_decay=0.0, muon_lr_scale_mode="none"))
    st = opt.init(p)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 64))}
    p2, _ = opt.step(p, g, st)
    s = jnp.linalg.svd(np.asarray(p2["w"], np.float32) / 0.1, compute_uv=False)
    assert 0.3 < float(s.min()) and float(s.max()) < 1.6


def test_cosine_schedule_decays_to_min_ratio():
    sched = cosine_schedule(1.0, total_steps=100, warmup_steps=10, min_ratio=0.1)
    assert float(sched(0)) < 0.11  # warmup start
    assert abs(float(sched(10)) - 1.0) < 1e-5
    assert abs(float(sched(100)) - 0.1) < 1e-5


def test_nesterov_matches_paper_eq3():
    theta = {"w": jnp.full((2,), 1.0)}
    psi = {"w": jnp.full((2,), 0.5)}
    st = nesterov_init(theta)
    lr, mu = 0.7, 0.9
    t1, st = nesterov_step(theta, psi, st, lr=lr, momentum=mu)
    # u1 = mu*0 + lr*psi ; theta1 = theta - mu*u1 - lr*psi
    u1 = lr * 0.5
    np.testing.assert_allclose(np.asarray(t1["w"]), 1.0 - mu * u1 - lr * 0.5, rtol=1e-6)
    t2, st = nesterov_step(t1, psi, st, lr=lr, momentum=mu)
    u2 = mu * u1 + lr * 0.5
    np.testing.assert_allclose(np.asarray(t2["w"]),
                               np.asarray(t1["w"]) - mu * u2 - lr * 0.5, rtol=1e-6)


def test_nesterov_kernel_routing_matches_xla():
    """The fused Pallas outer kernel is a drop-in for the XLA transform."""
    theta = {"w": jax.random.normal(jax.random.PRNGKey(0), (37, 5))}
    psi = {"w": jax.random.normal(jax.random.PRNGKey(1), (37, 5))}
    t_x = nesterov(0.7, 0.9)
    t_k = nesterov(0.7, 0.9, kernel=True)
    sx, sk = t_x.init(theta), t_k.init(theta)
    for _ in range(2):
        px, sx = t_x.apply(theta, psi, sx)
        pk, sk = t_k.apply(theta, psi, sk)
    np.testing.assert_allclose(np.asarray(px["w"]), np.asarray(pk["w"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(sx["u"]["w"]), np.asarray(sk["u"]["w"]),
                               rtol=1e-6)


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16"])
def test_optimizer_state_dtype_policy(state_dtype):
    p = _params()
    st = muon(OptimizerConfig(state_dtype=state_dtype)).init(p)
    # momentum for hidden matrices lives in the 'muon' partition's
    # trace_momentum stage
    m = st["tx"]["muon"][0]["m"]["layers"]["mlp"]["w_in"]
    assert m.dtype == jnp.dtype(state_dtype)
    # the AdamW-fallback second moment too
    v = st["tx"]["adamw"]["v"]["embed"]
    assert v.dtype == jnp.dtype(state_dtype)


# ---------------------------------------------------------------------------
# Transform combinators
# ---------------------------------------------------------------------------


def _double():
    return stateless(lambda u, p: jax.tree.map(lambda x: 2.0 * x, u))


def _add_one():
    return stateless(lambda u, p: jax.tree.map(lambda x: x + 1.0, u))


def test_chain_is_associative_on_updates():
    p = {"a": jnp.arange(4.0), "b": {"c": jnp.ones((2, 3))}}
    g = jax.tree.map(lambda x: x + 0.5, p)
    variants = [
        chain(_double(), _add_one(), _double()),
        chain(chain(_double(), _add_one()), _double()),
        chain(_double(), chain(_add_one(), _double())),
        chain(identity(), _double(), _add_one(), _double(), identity()),
    ]
    outs = []
    for tx in variants:
        u, _ = tx.update(g, tx.init(p), p)
        outs.append(u)
    for u in outs[1:]:
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), outs[0], u)


def test_chain_rejects_nonterminal_apply():
    with pytest.raises(ValueError, match="terminal"):
        chain(nesterov(0.7, 0.9), identity())


def test_generic_chain_builds_momentum_sgd():
    """A new optimizer in two lines: trace_momentum | scale_by_schedule with
    the default p+u application — the API the variant modules build on."""
    from repro.optim import apply_updates, scale_by_schedule

    lr, b1 = 0.1, 0.9
    tx = chain(trace_momentum(OptimizerConfig(b1=b1)),
               scale_by_schedule(lambda count: jnp.float32(-lr)))
    p = {"w": jnp.ones((3, 3))}
    st = tx.init(p)
    m_ref = np.zeros((3, 3), np.float32)
    for step in range(3):
        g = {"w": jnp.full((3, 3), float(step + 1))}
        u, st = tx.update(g, st, p)
        p = apply_updates(p, u)
        m_ref = b1 * m_ref + (step + 1)
    np.testing.assert_allclose(np.asarray(p["w"]),
                               _sgd_trajectory(m_ref_steps=3, lr=lr, b1=b1),
                               rtol=1e-6)


def _sgd_trajectory(m_ref_steps: int, lr: float, b1: float) -> np.ndarray:
    p = np.ones((3, 3), np.float32)
    m = np.zeros((3, 3), np.float32)
    for step in range(m_ref_steps):
        m = b1 * m + (step + 1)
        p = p - lr * m
    return p


def test_partition_routes_exactly_like_the_adamw_pattern():
    """Hidden matrices -> 'muon', embed/norm/bias/head -> 'adamw', matching
    the legacy _ADAMW_PATTERN split leaf for leaf."""
    p = _params()
    tag_mu = stateless(lambda u, _: jax.tree.map(lambda x: x * 0 + 1.0, u))
    tag_ad = stateless(lambda u, _: jax.tree.map(lambda x: x * 0 - 1.0, u))
    tx = partition(muon_label, {"muon": tag_mu, "adamw": tag_ad})
    u, _ = tx.update(p, tx.init(p), p)
    for (path, leaf), (_, lab) in zip(tree_leaves_with_paths(u),
                                      tree_leaves_with_paths(param_labels(p))):
        want = 1.0 if lab == "muon" else -1.0
        assert float(np.asarray(leaf).ravel()[0]) == want, (path, lab)


def test_partition_state_only_holds_owned_leaves():
    p = _params()
    st = partition(muon_label, {"muon": trace_momentum(OptimizerConfig()),
                                "adamw": scale_by_adam(OptimizerConfig())}).init(p)
    muon_paths = {path for path, _ in tree_leaves_with_paths(st["muon"])}
    assert not any("embed" in path or "norm" in path for path in muon_paths)
    adamw_paths = {path for path, _ in tree_leaves_with_paths(st["adamw"])}
    assert not any("w_in" in path for path in adamw_paths)


def test_partition_unknown_label_raises():
    with pytest.raises(ValueError, match="no transform"):
        partition(lambda path, leaf: "mystery", {"muon": identity()}).init(_params())


def test_muon_bp_reduces_to_muon_at_period_1():
    p = _params()
    g1, g2 = _grads(1), _grads(2)
    cfg = OptimizerConfig(lr=0.05, weight_decay=1e-4, ns_period=1)
    o_m, o_bp = muon(cfg), muon_bp(cfg)
    pm, sm = p, o_m.init(p)
    pb, sb = p, o_bp.init(p)
    for g in (g1, g2):
        pm, sm = o_m.step(pm, g, sm)
        pb, sb = o_bp.step(pb, g, sb)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), pm, pb)


def test_muon_bp_skips_ns_between_periods():
    """At period 2, step 2 applies raw momentum (not orthogonalized): the
    hidden update's singular values stay far from the NS plateau."""
    p = {"w": jnp.zeros((16, 64))}
    cfg = OptimizerConfig(lr=1.0, weight_decay=0.0, muon_lr_scale_mode="none",
                          ns_period=2)
    opt = muon_bp(cfg)
    st = opt.init(p)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 64)) * 1e-3}
    p1, st = opt.step(p, g, st)        # step 1: orthogonalized, O(1) svals
    s1 = np.linalg.svd(np.asarray(p1["w"]), compute_uv=False)
    assert s1.max() > 0.3
    p2, st = opt.step(p1, g, st)       # step 2: momentum-SGD, tiny update
    step2 = np.asarray(p2["w"] - p1["w"])
    assert np.abs(step2).max() < 1e-2


def test_normuon_state_dtype_respects_policy():
    p = _params()
    for sdt in ("float32", "bfloat16"):
        st = normuon(OptimizerConfig(state_dtype=sdt)).init(p)
        # chain: (trace_momentum, orthogonalize, scale_by_neuron_rms)
        v = st["tx"]["muon"][2]["v"]["layers"]["mlp"]["w_in"]
        assert v.dtype == jnp.dtype(sdt)
        # neuron-wise: one column per output neuron, not a full matrix
        assert v.shape == (2, 16, 1)
    p2, _ = (lambda o, s: o.step(p, _grads(0), s))(
        normuon(OptimizerConfig(lr=0.05)), normuon(OptimizerConfig(lr=0.05)).init(p))
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(p2))
