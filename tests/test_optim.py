"""Optimizer unit tests: AdamW semantics, Muon labeling/structure, schedules,
Nesterov outer update, memory-complexity claim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    OptimizerConfig,
    adamw,
    cosine_schedule,
    muon,
    nesterov_init,
    nesterov_step,
    param_labels,
)
from repro.utils.tree import tree_bytes


def _params():
    return {
        "embed": jnp.ones((32, 16)),
        "layers": {
            "attn": {"wq": jnp.ones((2, 16, 16)), "q_norm_scale": jnp.ones((2, 4))},
            "mlp": {"w_in": jnp.ones((2, 16, 32)), "w_out": jnp.ones((2, 32, 16))},
        },
        "head": jnp.ones((16, 32)),
        "final_norm_scale": jnp.ones((16,)),
    }


def test_param_labels():
    labels = param_labels(_params())
    assert labels["embed"] == "adamw"
    assert labels["head"] == "adamw"
    assert labels["final_norm_scale"] == "adamw"
    assert labels["layers"]["attn"]["wq"] == "muon"
    assert labels["layers"]["attn"]["q_norm_scale"] == "adamw"
    assert labels["layers"]["mlp"]["w_in"] == "muon"


def test_adamw_first_step_is_lr_sized():
    """With bias correction, |first step| ~= lr for any gradient scale."""
    p = {"w": jnp.zeros((4, 4))}
    for gscale in (1e-3, 1.0, 1e3):
        opt = adamw(OptimizerConfig(lr=0.01, weight_decay=0.0))
        st = opt.init(p)
        g = {"w": jnp.full((4, 4), gscale)}
        p2, _ = opt.step(p, g, st)
        np.testing.assert_allclose(np.asarray(p2["w"]), -0.01, rtol=1e-3)


def test_adamw_weight_decay_decoupled():
    p = {"w": jnp.full((2, 2), 10.0)}
    opt = adamw(OptimizerConfig(lr=0.1, weight_decay=0.5))
    st = opt.init(p)
    g = {"w": jnp.zeros((2, 2))}
    p2, _ = opt.step(p, g, st)
    # zero grad: update is pure decay p - lr*wd*p
    np.testing.assert_allclose(np.asarray(p2["w"]), 10.0 - 0.1 * 0.5 * 10.0, rtol=1e-5)


def test_muon_memory_advantage():
    """Paper Tab. 9: Muon holds 3 param copies vs AdamW's 4 (no 2nd moment
    for hidden matrices)."""
    p = _params()
    st_m = muon(OptimizerConfig()).init(p)
    st_a = adamw(OptimizerConfig()).init(p)
    assert tree_bytes(st_m) < 0.75 * tree_bytes(st_a)


def test_muon_hidden_update_is_orthonormal_scale():
    p = {"w": jnp.zeros((16, 64))}
    opt = muon(OptimizerConfig(lr=0.1, weight_decay=0.0, muon_lr_scale_mode="none"))
    st = opt.init(p)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 64))}
    p2, _ = opt.step(p, g, st)
    s = jnp.linalg.svd(np.asarray(p2["w"], np.float32) / 0.1, compute_uv=False)
    assert 0.3 < float(s.min()) and float(s.max()) < 1.6


def test_cosine_schedule_decays_to_min_ratio():
    sched = cosine_schedule(1.0, total_steps=100, warmup_steps=10, min_ratio=0.1)
    assert float(sched(0)) < 0.11  # warmup start
    assert abs(float(sched(10)) - 1.0) < 1e-5
    assert abs(float(sched(100)) - 0.1) < 1e-5


def test_nesterov_matches_paper_eq3():
    theta = {"w": jnp.full((2,), 1.0)}
    psi = {"w": jnp.full((2,), 0.5)}
    st = nesterov_init(theta)
    lr, mu = 0.7, 0.9
    t1, st = nesterov_step(theta, psi, st, lr=lr, momentum=mu)
    # u1 = mu*0 + lr*psi ; theta1 = theta - mu*u1 - lr*psi
    u1 = lr * 0.5
    np.testing.assert_allclose(np.asarray(t1["w"]), 1.0 - mu * u1 - lr * 0.5, rtol=1e-6)
    t2, st = nesterov_step(t1, psi, st, lr=lr, momentum=mu)
    u2 = mu * u1 + lr * 0.5
    np.testing.assert_allclose(np.asarray(t2["w"]),
                               np.asarray(t1["w"]) - mu * u2 - lr * 0.5, rtol=1e-6)


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16"])
def test_optimizer_state_dtype_policy(state_dtype):
    p = _params()
    st = muon(OptimizerConfig(state_dtype=state_dtype)).init(p)
    assert st["m"]["layers"]["mlp"]["w_in"].dtype == jnp.dtype(state_dtype)
