"""Attention backend equivalences: dense XLA <-> blockwise XLA <-> Pallas
flash kernel (interpret mode), forward AND gradients, plus grid-level proofs
that block skipping visits the schedule bound and changes nothing."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import (
    attention_schedule,
    clamp_block,
    gqa_flash_attention,
    visited_fraction,
    visited_kv_range,
)
from repro.models import ModelConfig, attention as A

CASES = [
    # (causal, window, H, KV)  — GQA G>1, MQA-ish, MHA, sliding-window
    (True, 0, 4, 2),
    (True, 12, 4, 2),
    (True, 0, 4, 4),
    (True, 8, 4, 1),
    (False, 0, 4, 2),
]


def _qkv(S, H, KV, hd, seed=0):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (2, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, S, KV, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, S, KV, hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal,window,H,KV", CASES)
def test_flash_matches_dense_ref_forward_and_grad(causal, window, H, KV):
    """Pallas kernel == jitted jnp oracle to fp32 tolerance, fwd + grads
    (value_and_grad drives the custom VJP's dq/dk/dv kernels)."""
    S, hd = 48, 16
    q, k, v = _qkv(S, H, KV, hd)
    flash = functools.partial(gqa_flash_attention, causal=causal,
                              window=window, block_q=16, block_kv=8)
    oracle = functools.partial(ref.gqa_attention_ref, causal=causal,
                               window=window)
    out = jax.jit(flash)(q, k, v)
    exp = jax.jit(oracle)(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)

    def loss(fn):
        # sin() makes the cotangent vary per element (catches transposed
        # or mis-scaled backward terms a sum() cotangent would hide)
        return jax.jit(jax.value_and_grad(
            lambda q, k, v: jnp.sum(jnp.sin(fn(q, k, v))), argnums=(0, 1, 2)))

    lv, g = loss(flash)(q, k, v)
    le, ge = loss(oracle)(q, k, v)
    assert abs(float(lv) - float(le)) < 1e-4
    for got, exp_g in zip(g, ge):
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp_g),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 12), (False, 0),
                                           (False, 12)])
def test_flash_matches_xla_blockwise(causal, window):
    """dense <-> XLA blockwise <-> Pallas: all three agree on one input —
    including causal=False with a sliding-window config, where all paths
    must agree the window only applies under causal masking."""
    S, H, KV, hd = 64, 4, 2, 16
    q, k, v = _qkv(S, H, KV, hd)
    cfg = ModelConfig(n_heads=H, n_kv_heads=KV, d_model=H * hd, head_dim=hd,
                      dtype="float32", qk_norm=False, sliding_window=window)
    blocked = jax.jit(lambda q, k, v: A._blockwise_attention(
        cfg, q, k, v, causal=causal, block_q=16, block_kv=16))(q, k, v)
    flash = jax.jit(lambda q, k, v: gqa_flash_attention(
        q, k, v, causal=causal, window=window if causal else 0,
        block_q=16, block_kv=16))(q, k, v)
    dense = jax.jit(lambda q, k, v: ref.gqa_attention_ref(
        q, k, v, causal=causal, window=window if causal else 0))(q, k, v)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [0, 16])
def test_attend_pallas_equals_xla(window):
    """The full attend() path (projections, RoPE, QK-norm) dispatched through
    attn_impl='pallas' matches the XLA paths, fwd + param/input grads."""
    S = 32
    base = ModelConfig(n_heads=4, n_kv_heads=2, d_model=64, head_dim=16,
                      d_ff=64, vocab=64, dtype="float32", qk_norm=True,
                      sliding_window=window, attn_block_q=8, attn_block_kv=8)
    p = A.init_attention(jax.random.PRNGKey(0), base)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, S, 64), jnp.float32)
    pos = jnp.arange(S)

    def run(cfg):
        fwd = jax.jit(lambda p, x: A.attend(p, cfg, x, pos))
        val, grads = jax.jit(jax.value_and_grad(
            lambda p, x: jnp.sum(jnp.sin(A.attend(p, cfg, x, pos))),
            argnums=(0, 1)))(p, x)
        return fwd(p, x), val, grads

    o_x, l_x, g_x = run(base)  # dense (S < threshold)
    o_b, l_b, g_b = run(base.replace(blockwise_threshold=S))  # blockwise
    o_p, l_p, g_p = run(base.replace(attn_impl="pallas"))  # flash kernel
    for o, lv, g in [(o_b, l_b, g_b), (o_p, l_p, g_p)]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_x),
                                   rtol=3e-5, atol=3e-5)
        assert abs(float(lv) - float(l_x)) < 1e-4
        for got, exp in zip(jax.tree.leaves(g), jax.tree.leaves(g_x)):
            np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                       rtol=5e-4, atol=5e-4)


def test_stacked_layer_lm_loss_and_grads_match():
    """Whole-model equivalence: a 2-layer scan-over-layers LM trained through
    attn_impl='pallas' (value_and_grad through the custom VJP inside vmap +
    scan + remat) matches attn_impl='xla' loss and gradients."""
    from repro.models import build_model

    cfg = ModelConfig(arch_type="dense", n_layers=2, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=64, vocab=64, dtype="float32",
                      qk_norm=True, remat=True, attn_block_q=8,
                      attn_block_kv=8)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, 64)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    outs = {}
    for impl in ("xla", "pallas"):
        model = build_model(cfg.replace(attn_impl=impl))
        params = model.init(jax.random.PRNGKey(0))
        (loss, _), grads = jax.jit(jax.value_and_grad(
            model.loss, has_aux=True))(params, batch)
        outs[impl] = (float(loss), grads)
    assert abs(outs["xla"][0] - outs["pallas"][0]) < 1e-5
    for a, b in zip(jax.tree.leaves(outs["xla"][1]),
                    jax.tree.leaves(outs["pallas"][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# Block skipping: proofs on the grid itself, and skipped == unskipped
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,bq,bkv", [(64, 8, 8), (64, 8, 16), (128, 16, 16),
                                      (128, 32, 16), (256, 64, 64)])
def test_causal_schedule_visits_at_most_half_plus_diagonal(S, bq, bkv):
    """The causal grid provably runs <= nq*nkv/2 + nq kv-blocks — asserted
    on the schedule the kernel grids over, not on timing."""
    nq, nkv = S // bq, S // bkv
    sched = attention_schedule(nq, nkv, bq, bkv, causal=True, window=0)
    assert len(sched) <= nq * nkv // 2 + nq
    # and it is exactly the brute-force visited set
    def visited(qi, kj):
        rows = np.arange(qi * bq, (qi + 1) * bq)
        cols = np.arange(kj * bkv, (kj + 1) * bkv)
        return bool((rows[:, None] >= cols[None, :]).any())
    brute = [(qi, kj) for qi in range(nq) for kj in range(nkv)
             if visited(qi, kj)]
    assert sched == brute


@pytest.mark.parametrize("S,window", [(128, 16), (128, 32), (256, 32)])
def test_window_schedule_is_o_window_over_s(S, window):
    """Sliding-window schedules visit O(window/S) of the grid: each q block
    scans a contiguous range of at most window/bkv + 2 kv blocks."""
    bq = bkv = 16
    nq, nkv = S // bq, S // bkv
    per_q = [visited_kv_range(qi, nkv, bq, bkv, True, window)
             for qi in range(nq)]
    assert all(hi - lo <= window // bkv + 2 for lo, hi in per_q)
    assert visited_fraction(S, bq, bkv, True, window) <= (window / S) + 3 * bkv / S
    # the q-major schedule is exactly the concatenation of the ranges
    sched = attention_schedule(nq, nkv, bq, bkv, True, window)
    flat = [(qi, kj) for qi, (lo, hi) in enumerate(per_q)
            for kj in range(lo, hi)]
    assert sched == flat


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 12)])
def test_block_skipping_is_exact(causal, window):
    """Skipped blocks change nothing: skip vs full-sweep grids are bitwise
    identical, for the Pallas kernel AND the XLA blockwise fallback."""
    S, H, KV, hd = 64, 4, 2, 8
    q, k, v = _qkv(S, H, KV, hd)
    f_skip = jax.jit(lambda q, k, v: gqa_flash_attention(
        q, k, v, causal=causal, window=window, block_q=8, block_kv=8,
        skip_blocks=True))(q, k, v)
    f_full = jax.jit(lambda q, k, v: gqa_flash_attention(
        q, k, v, causal=causal, window=window, block_q=8, block_kv=8,
        skip_blocks=False))(q, k, v)
    np.testing.assert_array_equal(np.asarray(f_skip), np.asarray(f_full))

    cfg = ModelConfig(n_heads=H, n_kv_heads=KV, d_model=H * hd, head_dim=hd,
                      dtype="float32", qk_norm=False, sliding_window=window)
    b_skip = jax.jit(lambda q, k, v: A._blockwise_attention(
        cfg, q, k, v, causal=causal, block_q=8, block_kv=8,
        skip_blocks=True))(q, k, v)
    b_full = jax.jit(lambda q, k, v: A._blockwise_attention(
        cfg, q, k, v, causal=causal, block_q=8, block_kv=8,
        skip_blocks=False))(q, k, v)
    np.testing.assert_array_equal(np.asarray(b_skip), np.asarray(b_full))


def test_block_clamping_divides_any_sequence():
    for S in (16, 48, 96, 4096):
        for b in (512, 1024, 7):
            assert S % clamp_block(b, S) == 0
            assert clamp_block(b, S) <= max(b, 1)


def test_visited_fraction_causal_is_about_half():
    f = visited_fraction(4096, 512, 1024, causal=True, window=0)
    assert 0.5 < f <= 0.5 + 1024 / 4096 + 1e-9
    assert visited_fraction(4096, 512, 1024, causal=False, window=0) == 1.0
