"""Elastic DiLoCo: worker churn, stragglers, and delayed outer sync.

Fault-injection harness invariants:

* an all-ones participation mask is **bitwise identical** to the dense
  (non-elastic) program — the engine's runtime cond dispatches the literal
  maskless computation whenever nobody dropped;
* a dropped worker freezes in place: EF residual and inner-optimizer state
  come back bit-identical, and rejoin is the normal sync broadcast;
* the masked reduce is exactly the subset mean over surviving workers, for
  every wire format;
* ``sync_delay`` applies the pseudogradient through the pending FIFO, late;
* the straggler wall-clock model collapses to the deterministic estimate at
  zero variance and its tail is monotone in the drop rate;
* the train CLI completes a scripted K=4 drop/rejoin run with --sync-delay 1
  and logs ``active_workers`` / ``staleness`` to metrics.csv.
"""
import csv
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DiLoCoConfig, make_outer
from repro.core.collectives import measured_sync_bytes
from repro.core.compression import CompressionConfig
from repro.core.faults import FaultPlan, parse_drop_schedule
from repro.core.wallclock import (
    RunSpec,
    StragglerModel,
    straggler_round_times,
    straggler_stats,
)
from repro.core.wire import decode_leaf, encode_leaf
from repro.data import DataConfig, MarkovStream, batches_for_round, batches_for_span
from repro.engine import TrainEngine
from repro.models import ModelConfig, build_model
from repro.optim import OptimizerConfig

CFG = ModelConfig(arch_type="dense", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                  d_ff=64, vocab=64, remat=False, dtype="float32", qk_norm=True)
ICFG = OptimizerConfig(lr=1e-2, weight_decay=0.0)

WIRE = {
    "none": CompressionConfig(kind="none"),
    "quant": CompressionConfig(kind="quant", bits=4, rowwise=True,
                               error_feedback=True, collective="a2a_rs_ag"),
    "topk": CompressionConfig(kind="topk", topk_frac=0.25,
                              error_feedback=True, collective="gather"),
}


def _stream(n_workers, bs=2, s=16, seed=3):
    return MarkovStream(DataConfig(vocab=CFG.vocab, seq_len=s, batch_per_worker=bs,
                                   n_workers=n_workers, seed=seed))


def _engine(K=2, H=4, inner="muon", comp="none", elastic=False, sync_delay=0):
    dcfg = DiLoCoConfig(n_workers=K, sync_interval=H, inner_name=inner,
                        compression=WIRE[comp], elastic=elastic,
                        sync_delay=sync_delay)
    engine = TrainEngine(build_model(CFG), dcfg, ICFG)
    return engine, engine.init(jax.random.PRNGKey(0))


def _assert_trees_equal(a, b, what=""):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree.leaves(b)
    assert len(fa) == len(fb)
    for (path, la), lb in zip(fa, fb):
        np.testing.assert_array_equal(
            np.asarray(la), np.asarray(lb),
            err_msg=f"{what}{jax.tree_util.keystr(path)}")


# ---------------------------------------------------------------------------
# All-ones mask == dense program, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("comp", ["none", "quant", "topk"])
def test_all_ones_mask_bitwise_equals_dense(comp):
    """The elastic config under full participation replays the non-elastic
    engine exactly: params, losses, EF residuals, and comm_bytes all bitwise
    equal over 3 rounds (the runtime cond runs the literal dense program)."""
    e_dense, s_dense = _engine(comp=comp)
    e_el, s_el = _engine(comp=comp, elastic=True)
    assert s_el["participation"] is not None  # all-ones at init
    for r in range(3):
        batches = batches_for_round(_stream(2), r, 4)
        s_dense, i_dense = e_dense.step(s_dense, batches)
        s_el, i_el = e_el.step(s_el, batches)
        np.testing.assert_array_equal(np.asarray(i_dense["loss"]),
                                      np.asarray(i_el["loss"]))
        assert float(i_dense["comm_bytes"]) == float(i_el["comm_bytes"])
        assert float(i_el["active_workers"]) == 2.0
    _assert_trees_equal(s_dense["outer_params"], s_el["outer_params"], "outer.")
    _assert_trees_equal(s_dense["worker_params"], s_el["worker_params"], "worker.")
    if s_dense["ef"] is not None:
        _assert_trees_equal(s_dense["ef"], s_el["ef"], "ef.")


# ---------------------------------------------------------------------------
# Drop semantics: frozen state, subset reduce, rejoin broadcast
# ---------------------------------------------------------------------------


def test_drop_then_rejoin_preserves_ef_and_inner_state():
    """A dropped worker's EF residual and inner-optimizer state come back
    bit-identical through its dropped round; its params rejoin via the
    normal sync broadcast."""
    engine, state = _engine(K=3, H=2, comp="quant", elastic=True)
    # round 0: everyone participates -> EF residuals become nonzero
    state, _ = engine.step(state, batches_for_round(_stream(3), 0, 2))
    ef_before = jax.tree.map(lambda x: np.asarray(x[1]), state["ef"])
    inner_before = jax.tree.map(lambda x: np.asarray(x[1]), state["inner_state"])
    assert any(float(np.abs(l).max()) > 0 for l in jax.tree.leaves(ef_before))
    # round 1: worker 1 drops
    state, info = engine.step(state, batches_for_round(_stream(3), 1, 2),
                              participation=np.array([1, 0, 1], np.float32))
    assert float(info["active_workers"]) == 2.0
    _assert_trees_equal(
        ef_before, jax.tree.map(lambda x: x[1], state["ef"]), "ef.")
    _assert_trees_equal(
        inner_before, jax.tree.map(lambda x: x[1], state["inner_state"]), "inner.")
    # rejoin IS the broadcast: every worker (the dropped one included) left
    # the sync holding the new outer params
    for k in range(3):
        _assert_trees_equal(
            state["outer_params"],
            jax.tree.map(lambda x: x[k], state["worker_params"]), f"w{k}.")
    # round 2: the worker rejoins and trains again (the mask is per-round
    # driver input — it sticks in the state until overwritten)
    state, info = engine.step(state, batches_for_round(_stream(3), 2, 2),
                              participation=np.ones(3, np.float32))
    assert float(info["active_workers"]) == 3.0


@pytest.mark.parametrize("comp", ["none", "quant", "topk"])
def test_masked_reduce_equals_hand_computed_subset_mean(comp):
    """OuterOptimizer.reduce under a mask == encode/decode each surviving
    worker independently, then average exactly those workers."""
    import dataclasses

    K = 4
    ccfg = dataclasses.replace(WIRE[comp], error_feedback=False)
    dcfg = DiLoCoConfig(n_workers=K, sync_interval=2, compression=ccfg)
    outer = make_outer(dcfg)
    params = {"w": jnp.zeros((6, 8), jnp.float32)}
    deltas = {"w": jax.random.normal(jax.random.PRNGKey(7), (K, 6, 8))}
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    psi, _ = outer.reduce(params, deltas, None, participation=mask)
    if comp == "none":
        vals = deltas["w"].astype(jnp.float32)
    else:  # wire rows are per-worker independent: survivors' codes are
        # unchanged by the dropped workers' (never-sent) rows
        vals = decode_leaf(encode_leaf(deltas["w"], ccfg, batch_ndim=1),
                           impl=ccfg.wire_impl)
    hand = (vals[0] + vals[2]) * 0.5  # the two survivors, exactly
    if comp == "quant":  # a2a_rs_ag re-quantizes the reduced shard (Q2/D2)
        hand = decode_leaf(encode_leaf(hand, ccfg, batch_ndim=0),
                           impl=ccfg.wire_impl)
    np.testing.assert_array_equal(np.asarray(psi["w"]), np.asarray(hand))


def test_masked_round_comm_bytes_scale_by_surviving_fraction():
    engine, state = _engine(K=4, H=2, inner="adamw", comp="quant", elastic=True)
    dense = measured_sync_bytes(state["outer_params"], WIRE["quant"], 4)
    state, info = engine.step(state, batches_for_round(_stream(4), 0, 2),
                              participation=np.array([1, 0, 1, 0], np.float32))
    np.testing.assert_allclose(float(info["comm_bytes"]), dense * 0.5, rtol=1e-6)


# ---------------------------------------------------------------------------
# Delayed outer sync: the pending FIFO
# ---------------------------------------------------------------------------


def test_sync_delay_first_round_holds_outer_params():
    """With sync_delay=1 round 0 applies the FIFO's zero pseudogradient: the
    outer params hold still, and the fresh Psi_0 enters the queue."""
    engine, state = _engine(K=2, H=2, inner="adamw", sync_delay=1)
    p0 = jax.tree.map(np.asarray, state["outer_params"])
    state, info = engine.step(state, batches_for_round(_stream(2), 0, 2))
    assert float(info["staleness"]) == 1.0
    _assert_trees_equal(p0, state["outer_params"], "outer.")
    # pending[0] is exactly the fresh pseudogradient the round reduced
    _assert_trees_equal(jax.tree.map(lambda q: q[0], state["pending"]),
                        info["psi"], "pending.")
    # round 1 applies Psi_0: now the outer params move
    state, _ = engine.step(state, batches_for_round(_stream(2), 1, 2))
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(np.abs(np.asarray(a) - b).max()),
        state["outer_params"], p0))
    assert max(moved) > 0


def test_sync_delay_fifo_shifts_each_round():
    engine, state = _engine(K=2, H=2, inner="adamw", sync_delay=2)
    for r in range(3):
        state, info = engine.step(state, batches_for_round(_stream(2), r, 2))
        # tail of the FIFO is always the round's fresh psi
        _assert_trees_equal(jax.tree.map(lambda q: q[-1], state["pending"]),
                            info["psi"], f"r{r}.pending.")


def test_sync_delay_config_guards():
    model = build_model(CFG)
    from repro.core import diloco_init
    with pytest.raises(ValueError, match="outer optimizer"):
        diloco_init(model, DiLoCoConfig(n_workers=1, sync_interval=1,
                                        outer_enabled=False, sync_delay=1),
                    ICFG, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="streaming"):
        diloco_init(model, DiLoCoConfig(n_workers=2, sync_interval=4,
                                        streaming_partitions=2, sync_delay=1),
                    ICFG, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Superstep: elastic masks thread through the scan-over-R dispatch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("inner", ["adamw", "muon"])
def test_superstep_elastic_matches_sequential_rounds_bitwise(inner):
    """One R=3 dispatch with a per-round mask stack replays 3 sequential
    masked engine.step rounds exactly — drops included."""
    H, R = 4, 3
    masks = np.array([[1, 1], [1, 0], [1, 1]], np.float32)
    e1, s1 = _engine(H=H, inner=inner, elastic=True)
    losses = []
    for r in range(R):
        s1, info = e1.step(s1, batches_for_round(_stream(2), r, H),
                           participation=masks[r])
        losses.append(np.asarray(info["loss"]))

    e2, s2 = _engine(H=H, inner=inner, elastic=True)
    s2, out = e2.superstep(s2, batches_for_span(_stream(2), 0, H, R),
                           participation=masks)
    np.testing.assert_array_equal(np.asarray(out["loss"]), np.stack(losses))
    np.testing.assert_array_equal(np.asarray(out["active_workers"]),
                                  np.array([2.0, 1.0, 2.0], np.float32))
    _assert_trees_equal(s1["outer_params"], s2["outer_params"], "outer.")
    _assert_trees_equal(s1["worker_params"], s2["worker_params"], "worker.")


# ---------------------------------------------------------------------------
# FaultPlan: host-side mask generation
# ---------------------------------------------------------------------------


def test_parse_drop_schedule():
    assert parse_drop_schedule("1:2;1:3,4:0") == {1: (2, 3), 4: (0,)}
    assert parse_drop_schedule("") == {}
    with pytest.raises(ValueError, match="bad --drop-schedule"):
        parse_drop_schedule("1-2")
    with pytest.raises(ValueError, match="negative"):
        parse_drop_schedule("1:-2")


def test_fault_plan_masks_are_chunking_invariant():
    plan = FaultPlan(n_workers=4, drop_prob=0.4, seed=5)
    full = plan.masks(0, 8)
    np.testing.assert_array_equal(full[2:6], plan.masks(2, 4))
    np.testing.assert_array_equal(
        full, np.stack([plan.mask_for_round(r) for r in range(8)]))


def test_fault_plan_always_keeps_one_survivor():
    plan = FaultPlan(n_workers=3, drop_prob=1.0)
    assert plan.masks(0, 16).sum(axis=1).min() == 1.0
    sched = FaultPlan(n_workers=2, schedule={0: (0, 1)})
    assert sched.mask_for_round(0).sum() == 1.0
    assert sched.mask_for_round(1).sum() == 2.0  # rejoin after the round


# ---------------------------------------------------------------------------
# Straggler wall-clock model
# ---------------------------------------------------------------------------

_SPEC16 = RunSpec(n_params=1e8, n_active_params=1e8, batch_tokens=2**17,
                  seq_len=1024, n_steps=300, sync_interval=30, n_workers=16)


def test_straggler_zero_variance_reproduces_deterministic_exactly():
    stats = straggler_stats(_SPEC16, 1e9, StragglerModel())
    det = stats["deterministic_round_s"]
    assert stats["p50_round_s"] == det
    assert stats["p99_round_s"] == det
    assert stats["p99_over_det"] == 1.0
    times = straggler_round_times(_SPEC16, 1e9, StragglerModel())
    assert float(np.ptp(times)) == 0.0


def test_straggler_percentiles_monotone_in_drop_rate():
    """Common random numbers: raising drop_prob only removes workers from
    the round max, so p50/p99 are non-increasing — sampling noise included."""
    prev = None
    for drop in (0.0, 0.1, 0.3, 0.6):
        s = straggler_stats(_SPEC16, 1e9,
                            StragglerModel(sigma=0.5, drop_prob=drop))
        if prev is not None:
            assert s["p50_round_s"] <= prev["p50_round_s"]
            assert s["p99_round_s"] <= prev["p99_round_s"]
        prev = s
    assert prev["p99_round_s"] >= prev["p50_round_s"]


def test_straggler_tail_costs_more_at_higher_sigma():
    lo = straggler_stats(_SPEC16, 1e9, StragglerModel(sigma=0.1))
    hi = straggler_stats(_SPEC16, 1e9, StragglerModel(sigma=0.8))
    assert hi["p99_over_det"] > lo["p99_over_det"] > 1.0


def test_straggler_sample_keeps_one_survivor():
    lat, active = StragglerModel(sigma=0.5, drop_prob=1.0).sample(8)
    assert active.sum(axis=1).min() == 1
    assert lat.shape == active.shape


# ---------------------------------------------------------------------------
# Scenario: the train CLI under scripted churn + delayed sync
# ---------------------------------------------------------------------------


def test_train_cli_fault_scenario_completes_and_logs_columns(tmp_path):
    """A K=4 run with mid-run drops (workers 1 and 2 out for round 1) and
    --sync-delay 1 completes; metrics.csv carries active_workers/staleness;
    the final loss stays within a pinned tolerance of the lockstep run."""
    from repro.launch.train import build_parser, train

    base = ["--arch", "smollm-135m", "--reduced", "--inner", "adamw",
            "--lr", "4e-3", "--workers", "4", "--sync-interval", "2",
            "--rounds", "3", "--batch-per-worker", "2", "--seq-len", "32"]
    lockstep = train(build_parser().parse_args(
        base + ["--out", str(tmp_path / "lockstep")]))
    faulty = train(build_parser().parse_args(
        base + ["--drop-schedule", "1:1;1:2", "--sync-delay", "1",
                "--out", str(tmp_path / "faulty")]))

    with open(os.path.join(tmp_path, "faulty", "metrics.csv")) as f:
        rows = list(csv.DictReader(f))
    assert {"active_workers", "staleness"} <= set(rows[0])
    assert [float(r["active_workers"]) for r in rows] == [4.0, 2.0, 4.0]
    assert all(float(r["staleness"]) == 1.0 for r in rows)
    # the lockstep CSV carries the dense defaults in the same columns
    with open(os.path.join(tmp_path, "lockstep", "metrics.csv")) as f:
        dense_rows = list(csv.DictReader(f))
    assert all(float(r["active_workers"]) == 4.0 for r in dense_rows)
    assert all(float(r["staleness"]) == 0.0 for r in dense_rows)

    assert np.isfinite(faulty["final_loss"])
    # pinned degradation budget: churn + 1-round staleness on a 3-round run
    assert abs(faulty["final_loss"] - lockstep["final_loss"]) < 2.0
