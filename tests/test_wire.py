"""Wire-format collective invariants: pack -> reduce -> unpack equals the
reference dequantized reduce, EF residuals see the true reconstruction, and
measured comm_bytes match hand-computed buffer sizes."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig, DiLoCoConfig
from repro.core.collectives import (
    collective_bytes_tree,
    measured_compression_ratio,
    measured_sync_bytes,
    reduce_pseudogradients,
)
from repro.core.compression import compress, error_feedback, topk_sparsify
from repro.core.wire import (
    QuantWire,
    TopKWire,
    decode_leaf,
    encode_leaf,
    encode_tree,
    wire_tree_bytes,
)
from repro.kernels import ref
from repro.kernels.quantize import pack_codes, packed_width, unpack_codes


# ---------------------------------------------------------------------------
# Code bit-packing: lossless, exact wire width
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("n", [7, 64, 129])
def test_pack_codes_roundtrip_and_width(bits, n):
    codes = jax.random.randint(jax.random.PRNGKey(bits * n), (5, n), 0,
                               1 << min(bits, 8)).astype(jnp.uint8)
    packed = pack_codes(codes, bits)
    assert packed.shape[-1] == packed_width(n, bits)
    if 8 % bits == 0:
        assert packed.shape[-1] == math.ceil(n * bits / 8)
    np.testing.assert_array_equal(np.asarray(unpack_codes(packed, bits, n)),
                                  np.asarray(codes))


# ---------------------------------------------------------------------------
# Quant: the wire path matches the rowwise_quantize_ref composition
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["pallas", "jnp"])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quant_wire_roundtrip_matches_ref(impl, bits):
    """Enc -> wire buffers -> Dec == the reference quantize-dequantize,
    elementwise, for both backends (under jit, like the engine runs them)."""
    x = jax.random.normal(jax.random.PRNGKey(bits), (24, 96), jnp.float32) * 3

    @jax.jit
    def roundtrip(x):
        w = encode_leaf(x, CompressionConfig(kind="quant", bits=bits,
                                             rowwise=True, wire_impl=impl),
                        batch_ndim=0)
        return decode_leaf(w, impl=impl)

    expect = jax.jit(
        lambda x: ref.rowwise_quantize_ref(x, bits)[0])(x)
    np.testing.assert_array_equal(np.asarray(roundtrip(x)), np.asarray(expect))


@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_quant_wire_reduce_matches_ref_composition(impl):
    """pack -> reduce -> unpack == D(Q2(mean_k D(Q1(d_k)))) built from
    rowwise_quantize_ref — the paper's exactly-two-quantization collective,
    elementwise."""
    bits, K = 4, 3
    cfg = CompressionConfig(kind="quant", bits=bits, rowwise=True,
                            wire_impl=impl)
    deltas = jax.random.normal(jax.random.PRNGKey(0), (K, 16, 40), jnp.float32)

    @jax.jit
    def wire_path(deltas):
        comm = encode_leaf(deltas, cfg, batch_ndim=1)
        return reduce_pseudogradients({"w": comm}, cfg)["w"]

    @jax.jit
    def ref_path(deltas):
        q1 = jax.vmap(lambda d: ref.rowwise_quantize_ref(d, bits)[0])(deltas)
        psi = jnp.mean(q1, axis=0)
        return ref.rowwise_quantize_ref(psi, bits)[0]  # Q2 + D2

    np.testing.assert_array_equal(np.asarray(wire_path(deltas)),
                                  np.asarray(ref_path(deltas)))


def test_quant_global_rows_fold_workers():
    """rowwise=False treats each worker's whole leaf as one wire row."""
    cfg = CompressionConfig(kind="quant", bits=8, rowwise=False)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 10), jnp.float32)
    w = jax.jit(lambda x: encode_leaf(x, cfg, batch_ndim=1))(x)
    assert isinstance(w, QuantWire)
    assert w.lo.shape == (2, 1) and w.packed.shape == (2, 60)
    per_worker = jax.jit(lambda v: ref.rowwise_quantize_ref(v, 8)[0])(
        x.reshape(2, 60))
    np.testing.assert_array_equal(
        np.asarray(jax.jit(decode_leaf)(w)), np.asarray(per_worker.reshape(x.shape)))


# ---------------------------------------------------------------------------
# Top-k: (index, value) pairs reconstruct the sparsified tensor
# ---------------------------------------------------------------------------


def test_topk_wire_roundtrip_matches_sparsify():
    cfg = CompressionConfig(kind="topk", topk_frac=0.1, collective="gather")
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 17, 23), jnp.float32)
    w = jax.jit(lambda x: encode_leaf(x, cfg, batch_ndim=1))(x)
    assert isinstance(w, TopKWire)
    k = max(int(round(0.1 * 17 * 23)), 1)
    assert w.indices.shape == (3, k) and w.indices.dtype == jnp.int32
    assert w.values.shape == (3, k)
    dense = jax.jit(decode_leaf)(w)
    expect = jax.vmap(lambda v: topk_sparsify(v, 0.1))(x)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(expect))


def test_topk_wire_reduce_is_mean_of_sparse():
    cfg = CompressionConfig(kind="topk", topk_frac=0.25, collective="gather")
    deltas = jax.random.normal(jax.random.PRNGKey(3), (2, 40), jnp.float32)
    comm = jax.jit(lambda d: encode_tree({"w": d}, cfg, batch_ndim=1))(deltas)
    psi = reduce_pseudogradients(comm, cfg)["w"]
    expect = jnp.mean(jax.vmap(lambda v: topk_sparsify(v, 0.25))(deltas), axis=0)
    np.testing.assert_array_equal(np.asarray(psi), np.asarray(expect))


# ---------------------------------------------------------------------------
# EF: residual is computed against the true wire reconstruction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,kw", [
    ("quant", dict(bits=4, rowwise=True)),
    ("topk", dict(topk_frac=0.25, collective="gather")),
])
def test_ef_residual_equals_acc_minus_wire_reconstruction(kind, kw):
    cfg = CompressionConfig(kind=kind, error_feedback=True, ef_decay=0.9, **kw)
    ef = error_feedback(cfg)
    deltas = {"w": jax.random.normal(jax.random.PRNGKey(4), (2, 8, 12))}
    residuals = {"w": jax.random.normal(jax.random.PRNGKey(5), (2, 8, 12))}

    @jax.jit  # one program, so the reference acc CSEs with the stage's
    def run(deltas, residuals):
        comm, new_res = ef.update(deltas, residuals, None)
        acc = cfg.ef_decay * residuals["w"].astype(jnp.float32) \
            + deltas["w"].astype(jnp.float32)
        recon = decode_leaf(comm["w"], impl=cfg.wire_impl)
        return new_res["w"], acc - recon

    got, expect = run(deltas, residuals)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


def test_compress_stage_passthrough_for_none():
    """kind='none' must stay the bit-exact dense path (pinned losses)."""
    cfg = CompressionConfig(kind="none")
    stage = compress(cfg)
    deltas = {"w": jnp.arange(12.0).reshape(2, 6)}
    out, _ = stage.update(deltas, stage.init(deltas), None)
    assert out["w"] is deltas["w"]
    psi = reduce_pseudogradients(deltas, cfg)
    np.testing.assert_array_equal(np.asarray(psi["w"]),
                                  np.asarray(jnp.mean(deltas["w"], axis=0)))


# ---------------------------------------------------------------------------
# Measured comm_bytes == hand-computed buffer sizes
# ---------------------------------------------------------------------------


def _params():
    return {"a": jnp.zeros((8, 32)), "b": jnp.zeros((40,))}


def test_measured_bytes_quant_rowwise_hand_computed():
    K, bits = 2, 4
    cfg = CompressionConfig(kind="quant", bits=bits, rowwise=True)
    # leaf a [8,32] rowwise: 8 rows of 32 codes -> packed 16 B/row + 8 B
    # (lo+scale) metadata per row. Q1 per worker + Q2 once.
    a_rows, a_cols = 8, 32
    a_bytes = a_rows * (packed_width(a_cols, bits) + 8)
    # leaf b [40] is 1-D -> one global row per worker / for psi
    b_bytes = packed_width(40, bits) + 8
    expect = 2 * (a_bytes + b_bytes)  # Q1 (per worker) + Q2, same shapes
    assert measured_sync_bytes(_params(), cfg, K) == expect


def test_measured_bytes_topk_hand_computed():
    K, frac = 4, 0.1
    cfg = CompressionConfig(kind="topk", topk_frac=frac, collective="gather")
    # per leaf: K * k * (4 B index + 4 B value); all-gather grows with K
    k_a = max(int(round(frac * 8 * 32)), 1)
    k_b = max(int(round(frac * 40)), 1)
    expect = K * (k_a + k_b) * 8
    assert measured_sync_bytes(_params(), cfg, K) == expect
    # no metadata on the top-k wire, so measured only differs from the model
    # by the per-leaf (vs whole-tree) rounding of k
    modeled = collective_bytes_tree(_params(), cfg, K)["bytes_per_sync_per_worker"]
    assert abs(expect - modeled) <= K * 8 * len(jax.tree.leaves(_params()))


def test_measured_bytes_none_is_dense_fp32():
    K = 3
    cfg = CompressionConfig(kind="none")
    n = 8 * 32 + 40
    assert measured_sync_bytes(_params(), cfg, K) == 2 * n * 4


def test_measured_bytes_equal_actual_wire_buffers():
    """The eval_shape accounting equals bytes of concretely encoded buffers."""
    K = 2
    cfg = CompressionConfig(kind="quant", bits=4, rowwise=True)
    stacked = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (K, *p.shape)) + 1.0, _params())
    q1 = encode_tree(stacked, cfg, batch_ndim=1)
    q2 = encode_tree(_params(), cfg, batch_ndim=0)
    assert measured_sync_bytes(_params(), cfg, K) == (
        wire_tree_bytes(q1) // K + wire_tree_bytes(q2))


def test_measured_ratio_counts_overhead():
    cfg = CompressionConfig(kind="quant", bits=4, rowwise=True)
    ratio = measured_compression_ratio(_params(), cfg, 2)
    assert cfg.compression_ratio() == 0.125
    assert 0.125 < ratio < 0.25  # metadata rows cost real bytes


# ---------------------------------------------------------------------------
# Streaming (J>1) wire-row subsetting: segment syncs encode only their rows
# ---------------------------------------------------------------------------


def _stream_params():
    return {"layers": {"w": jnp.zeros((4, 6, 8)), "b": jnp.zeros((4, 8))},
            "embed": jnp.zeros((10, 4)), "scale": jnp.zeros((8,))}


@pytest.mark.parametrize("cfg", [
    CompressionConfig(kind="quant", bits=4, rowwise=True),
    CompressionConfig(kind="quant", bits=4, rowwise=True, error_feedback=True),
    CompressionConfig(kind="quant", bits=8),
    CompressionConfig(kind="topk", topk_frac=0.25, collective="gather"),
    CompressionConfig(kind="none"),
], ids=["quant_rw", "quant_rw_ef", "quant_global", "topk", "none"])
@pytest.mark.parametrize("J", [2, 3])
def test_segment_sync_bytes_sum_to_dense_single_sync(cfg, J):
    """Per-segment measured bytes must sum to the dense single-sync total —
    the subset shapes partition the wire rows exactly."""
    from repro.core.streaming import streaming_masks

    params = _stream_params()
    masks = streaming_masks(params, J)
    full = measured_sync_bytes(params, cfg, 3)
    segs = [measured_sync_bytes(params, cfg, 3, mask=m) for m in masks]
    assert sum(segs) == full, (segs, full)
    assert all(s < full for s in segs)  # every segment genuinely shrank


def test_segment_sync_update_subsets_rows_exactly():
    """For rowwise quantization the subset encode is row-independent, so the
    segment sync must equal the legacy full-size masked encode on owned rows
    bitwise, with psi exactly zero outside the partition and unowned EF
    residual rows untouched."""
    from repro.core.collectives import _leaf_wire_pipeline, segment_sync_update
    from repro.core.streaming import streaming_masks

    cfg = CompressionConfig(kind="quant", bits=4, rowwise=True,
                            error_feedback=True)
    K = 3
    key = jax.random.PRNGKey(0)
    deltas = {"layers": {"w": jax.random.normal(key, (K, 4, 6, 8))},
              "embed": jax.random.normal(jax.random.fold_in(key, 1), (K, 10, 4))}
    ef = jax.tree.map(
        lambda d: jax.random.normal(jax.random.fold_in(key, 2), d.shape), deltas)
    masks = streaming_masks({"layers": {"w": jnp.zeros((4, 6, 8))},
                             "embed": jnp.zeros((10, 4))}, 2)
    m = masks[0]
    masked = jax.tree.map(lambda mm, d: mm[None] * d if mm.ndim else mm * d,
                          m, deltas)

    @jax.jit  # one program so the two pipelines CSE identically
    def both(masked, ef):
        psi_s, ef_s = segment_sync_update(masked, ef, m, cfg)
        legacy = jax.tree.map(lambda d, e: _leaf_wire_pipeline(d, e, cfg),
                              masked, ef)
        is_pair = lambda t: isinstance(t, tuple)  # noqa: E731
        psi_l = jax.tree.map(lambda t: t[0], legacy, is_leaf=is_pair)
        ef_l = jax.tree.map(lambda t: t[1], legacy, is_leaf=is_pair)
        return psi_s, ef_s, psi_l, ef_l

    psi_s, ef_s, psi_l, ef_l = both(masked, ef)
    owned = np.asarray(m["layers"]["w"]).reshape(4) > 0
    assert owned.any() and not owned.all()
    np.testing.assert_array_equal(np.asarray(psi_s["layers"]["w"])[owned],
                                  np.asarray(psi_l["layers"]["w"])[owned])
    np.testing.assert_array_equal(np.asarray(ef_s["layers"]["w"])[:, owned],
                                  np.asarray(ef_l["layers"]["w"])[:, owned])
    assert bool(np.all(np.asarray(psi_s["layers"]["w"])[~owned] == 0))
    np.testing.assert_array_equal(  # unowned residual rows stay put
        np.asarray(ef_s["layers"]["w"])[:, ~owned],
        np.asarray(ef["layers"]["w"])[:, ~owned].astype(np.float32))


@pytest.mark.parametrize("cfg", [
    CompressionConfig(kind="quant", bits=4, rowwise=True, error_feedback=True),
    CompressionConfig(kind="quant", bits=4, rowwise=True),
    CompressionConfig(kind="topk", topk_frac=0.25, collective="gather",
                      error_feedback=True),
], ids=["quant_ef", "quant", "topk_ef"])
def test_leaf_wire_pipeline_matches_stage_chain(cfg):
    """segment_sync_update's per-leaf pipeline must stay bitwise-identical
    to the production worker_stage + reduce chain — if the chain's EF
    formula or Q2 condition ever changes in one place only, this breaks."""
    from repro.core.collectives import _leaf_wire_pipeline

    K = 3
    deltas = {"w": jax.random.normal(jax.random.PRNGKey(0), (K, 6, 8))}
    residuals = {"w": jax.random.normal(jax.random.PRNGKey(1), (K, 6, 8))}
    stage = (error_feedback(cfg) if cfg.error_feedback else compress(cfg))

    @jax.jit  # one program so both paths CSE identically
    def both(deltas, residuals):
        if cfg.error_feedback:
            comm, new_res = stage.update(deltas, residuals, None)
        else:
            comm, _ = stage.update(deltas, stage.init(deltas), None)
            new_res = None
        psi_chain = reduce_pseudogradients(comm, cfg)
        psi_leaf, res_leaf = _leaf_wire_pipeline(
            deltas["w"], residuals["w"] if cfg.error_feedback else None, cfg)
        return psi_chain["w"], new_res, psi_leaf, res_leaf

    psi_c, res_c, psi_l, res_l = both(deltas, residuals)
    np.testing.assert_array_equal(np.asarray(psi_c), np.asarray(psi_l))
    if cfg.error_feedback:
        np.testing.assert_array_equal(np.asarray(res_c["w"]),
                                      np.asarray(res_l))


def test_streaming_engine_round_comm_bytes_sum_to_dense():
    """A J=2 round through the engine: the summed per-segment comm_bytes in
    the round metric equal the dense single-sync bytes, and training runs."""
    from repro.data import DataConfig, MarkovStream, batches_for_round
    from repro.engine import TrainEngine
    from repro.models import ModelConfig, build_model
    from repro.optim import OptimizerConfig

    cfg = ModelConfig(arch_type="dense", n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab=64, remat=False,
                      dtype="float32", qk_norm=True)
    model = build_model(cfg)
    comp = CompressionConfig(kind="quant", bits=4, rowwise=True,
                             error_feedback=True)
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=2, inner_name="adamw",
                        compression=comp, streaming_partitions=2)
    engine = TrainEngine(model, dcfg, OptimizerConfig(lr=1e-2, weight_decay=0.0))
    state = engine.init(jax.random.PRNGKey(0))
    params_abs = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    dense_total = measured_sync_bytes(params_abs, comp, 2)

    stream = MarkovStream(DataConfig(vocab=64, seq_len=16, batch_per_worker=2,
                                     n_workers=2, seed=3))
    state, info = engine.step(state, batches_for_round(stream, 0, 2))
    assert float(info["comm_bytes"]) == dense_total
    assert np.isfinite(float(info["loss"].mean()))


# ---------------------------------------------------------------------------
# Engine integration: per-round comm_bytes lands in the metrics/history
# ---------------------------------------------------------------------------


def test_engine_round_reports_measured_comm_bytes():
    from repro.data import DataConfig, MarkovStream, batches_for_round
    from repro.engine import TrainEngine, run_rounds
    from repro.models import ModelConfig, build_model
    from repro.optim import OptimizerConfig

    cfg = ModelConfig(arch_type="dense", n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab=64, remat=False,
                      dtype="float32", qk_norm=True)
    model = build_model(cfg)
    comp = CompressionConfig(kind="quant", bits=4, rowwise=True)
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=2, inner_name="adamw",
                        compression=comp)
    engine = TrainEngine(model, dcfg, OptimizerConfig(lr=1e-2, weight_decay=0.0))
    state = engine.init(jax.random.PRNGKey(0))
    params_abs = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    expect = measured_sync_bytes(params_abs, comp, 2)

    stream = MarkovStream(DataConfig(vocab=64, seq_len=16, batch_per_worker=2,
                                     n_workers=2, seed=3))
    state, info = engine.step(state, batches_for_round(stream, 0, 2))
    assert float(info["comm_bytes"]) == expect

    _, history = run_rounds(
        engine, state, lambda r: batches_for_round(stream, r, 2), 3, start=1)
    assert [h["comm_bytes"] for h in history] == [float(expect)] * 2
