"""DiLoCo/MuLoCo algorithm invariants and equivalences."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    DiLoCoConfig,
    compute_deltas,
    diloco_init,
    diloco_round,
    dp_init,
    dp_step,
    inner_step,
    make_optimizer,
    make_streaming_masks,
    outer_step,
)
from repro.core.streaming import assert_masks_partition, streaming_masks
from repro.data import DataConfig, MarkovStream, batches_for_round
from repro.models import ModelConfig, build_model
from repro.optim import OptimizerConfig

CFG = ModelConfig(arch_type="dense", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                  d_ff=64, vocab=64, remat=False, dtype="float32", qk_norm=True)


def _setup(dcfg, lr=1e-2, seed=0):
    model = build_model(CFG)
    icfg = OptimizerConfig(lr=lr, weight_decay=0.0)
    opt = make_optimizer(dcfg, icfg)
    state = diloco_init(model, dcfg, icfg, jax.random.PRNGKey(seed))
    return model, opt, state


def _batch(dcfg, step=0, bs=2, s=16):
    stream = MarkovStream(DataConfig(vocab=CFG.vocab, seq_len=s, batch_per_worker=bs,
                                     n_workers=dcfg.n_workers, seed=3))
    return stream.batch(step)


def test_pseudogradient_is_param_delta():
    dcfg = DiLoCoConfig(n_workers=3, sync_interval=2, inner_name="adamw")
    model, opt, state = _setup(dcfg)
    for t in range(2):
        state, _ = inner_step(model, opt, state, _batch(dcfg, t))
    deltas = compute_deltas(state)
    d = deltas["layers"]["mlp"]["w_in"]
    manual = (state["outer_params"]["layers"]["mlp"]["w_in"][None]
              - state["worker_params"]["layers"]["mlp"]["w_in"])
    np.testing.assert_allclose(np.asarray(d), np.asarray(manual), rtol=1e-6)
    assert d.shape[0] == 3


@pytest.mark.parametrize("inner", ["adamw", "muon"])
def test_k1_h1_equals_inner_optimizer(inner):
    """DiLoCo(K=1, H=1, eta_out=1, mu=0) == plain inner optimizer."""
    dcfg = DiLoCoConfig(n_workers=1, sync_interval=1, inner_name=inner,
                        outer_lr=1.0, outer_momentum=0.0)
    model, opt, state = _setup(dcfg)
    dp_state, dp_opt = dp_init(model, inner, OptimizerConfig(lr=1e-2, weight_decay=0.0),
                               jax.random.PRNGKey(0))
    for t in range(3):
        batch = _batch(dcfg, t)
        state, _ = inner_step(model, opt, state, batch)
        state, _ = outer_step(dcfg, state)
        dp_state, _ = dp_step(model, dp_opt, dp_state, jax.tree.map(lambda x: x[0], batch))
    a = state["outer_params"]["layers"]["mlp"]["w_in"]
    b = dp_state["params"]["layers"]["mlp"]["w_in"]
    # The outer update computes theta - (theta - w): exact in real arithmetic
    # but fp-rounded; Muon's bf16 Newton-Schulz chaotically amplifies the
    # ~1e-7 rounding over steps, so its tolerance is looser than AdamW's.
    kw = dict(rtol=2e-2, atol=1e-3) if inner == "muon" else dict(rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **kw)


def test_workers_reset_to_outer_after_sync():
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=2, inner_name="muon")
    model, opt, state = _setup(dcfg)
    for t in range(2):
        state, _ = inner_step(model, opt, state, _batch(dcfg, t))
    state, _ = outer_step(dcfg, state)
    for path in (("embed",), ("layers", "mlp", "w_in")):
        o = state["outer_params"]
        w = state["worker_params"]
        for k in path:
            o, w = o[k], w[k]
        for i in range(2):
            np.testing.assert_allclose(np.asarray(w[i]), np.asarray(o), rtol=1e-6)


def test_identical_shards_make_identical_workers():
    """With identical per-worker data, all workers stay in lockstep."""
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=2, inner_name="muon")
    model, opt, state = _setup(dcfg)
    b = _batch(dcfg)
    same = jax.tree.map(lambda x: jnp.stack([x[0], x[0]]), b)
    state, _ = inner_step(model, opt, state, same)
    w = state["worker_params"]["layers"]["mlp"]["w_in"]
    np.testing.assert_allclose(np.asarray(w[0]), np.asarray(w[1]), rtol=1e-6)


def test_streaming_masks_partition_everything():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    for j in (2, 3):
        masks = streaming_masks(params, j)
        assert assert_masks_partition(masks)


def test_streaming_equals_dense_when_j1():
    dcfg_j1 = DiLoCoConfig(n_workers=2, sync_interval=4, inner_name="muon")
    dcfg_j2 = DiLoCoConfig(n_workers=2, sync_interval=4, inner_name="muon",
                           streaming_partitions=2)
    losses = {}
    for name, dcfg in [("dense", dcfg_j1), ("stream", dcfg_j2)]:
        model, opt, state = _setup(dcfg)
        masks = make_streaming_masks(state, dcfg)
        stream = MarkovStream(DataConfig(vocab=CFG.vocab, seq_len=16, batch_per_worker=2,
                                         n_workers=2, seed=3))
        for r in range(3):
            batches = batches_for_round(stream, r, dcfg.sync_interval)
            state, info = diloco_round(model, dcfg, opt, state, batches, masks=masks)
        losses[name] = float(info["loss"][-1])
    # same data, same inner opt: streaming must track dense closely
    assert abs(losses["dense"] - losses["stream"]) < 0.15 * losses["dense"]


def test_quantized_sync_close_to_exact():
    base = DiLoCoConfig(n_workers=2, sync_interval=2, inner_name="muon")
    q8 = DiLoCoConfig(n_workers=2, sync_interval=2, inner_name="muon",
                      compression=CompressionConfig(kind="quant", bits=8))
    outs = {}
    for name, dcfg in [("exact", base), ("q8", q8)]:
        model, opt, state = _setup(dcfg)
        for t in range(2):
            state, _ = inner_step(model, opt, state, _batch(dcfg, t))
        state, psi = outer_step(dcfg, state)
        outs[name] = psi["layers"]["mlp"]["w_in"]
    err = float(jnp.max(jnp.abs(outs["exact"] - outs["q8"])))
    scale = float(jnp.max(jnp.abs(outs["exact"])))
    assert err < 0.02 * scale + 1e-7


def test_ef_state_updates_only_with_compression():
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=1, inner_name="muon",
                        compression=CompressionConfig(kind="topk", topk_frac=0.25,
                                                      error_feedback=True, ef_decay=1.0,
                                                      collective="gather"))
    model, opt, state = _setup(dcfg)
    state, _ = inner_step(model, opt, state, _batch(dcfg))
    deltas = compute_deltas(state)
    state2, _ = outer_step(dcfg, state)
    # EF invariant (ef_decay=1): residual + communicated == accumulated delta
    d = deltas["layers"]["mlp"]["w_in"]
    e = state2["ef"]["layers"]["mlp"]["w_in"]
    # communicated = delta - residual (first round, E0=0)
    comm = d - e
    # residual has exactly (1 - frac) of entries non-zero pattern per worker
    nz = np.count_nonzero(np.asarray(comm[0]))
    total = comm[0].size
    assert abs(nz / total - 0.25) < 0.05


def test_round_jits_and_trains():
    dcfg = DiLoCoConfig(n_workers=2, sync_interval=3, inner_name="muon")
    model, opt, state = _setup(dcfg, lr=2e-2)
    stream = MarkovStream(DataConfig(vocab=CFG.vocab, seq_len=16, batch_per_worker=4,
                                     n_workers=2, seed=1))
    fn = jax.jit(functools.partial(diloco_round, model, dcfg, opt, masks=None))
    first = last = None
    for r in range(6):
        state, info = fn(state, batches_for_round(stream, r, 3))
        if first is None:
            first = float(info["loss"].mean())
        last = float(info["loss"].mean())
    assert last < first
