"""Integration: the dry-run machinery on a small forced-device-count world.

Runs in a subprocess because XLA pins the device count at first
initialization — the main pytest process must keep its single CPU device.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs import get_config, reduce_config
from repro.core.diloco import DiLoCoConfig
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import build_plans
from repro.roofline.hlo import collective_bytes_corrected

mesh = make_debug_mesh(data=2, model=2, pod=2)
out = {}
for arch, shape in [("smollm-135m", "train_4k"), ("mamba2-370m", "decode_32k"),
                    ("deepseek-moe-16b", "prefill_32k")]:
    cfg = reduce_config(get_config(arch))
    # shrink the shapes too: patch INPUT_SHAPES locally via small seq
    from repro.configs import base as cb
    cb.INPUT_SHAPES["train_4k"] = cb.InputShape("train_4k", 64, 8, "train")
    cb.INPUT_SHAPES["decode_32k"] = cb.InputShape("decode_32k", 64, 4, "decode")
    cb.INPUT_SHAPES["prefill_32k"] = cb.InputShape("prefill_32k", 64, 4, "prefill")
    plans = build_plans(cfg, shape, mesh, **(
        {"dcfg": DiLoCoConfig(n_workers=2, sync_interval=4)} if shape == "train_4k" else {}))
    for plan in plans:
        with mesh:
            c = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                        donate_argnums=plan.donate).lower(*plan.args).compile()
        coll = collective_bytes_corrected(c.as_text())
        rec = {"ok": True, "collective_total": coll["total"]}
        if plan.name in ("round_step", "superstep"):
            from repro.launch.dryrun import round_step_donation_report
            rec["donation"] = round_step_donation_report(
                plan.args[0], c.as_text(), c.memory_analysis(),
                mesh.devices.size)
        out[f"{arch}/{shape}/{plan.name}"] = rec

# no-pod regression config: on a single-pod mesh whose 'model' axis is wider
# than 'data', GSPMD used to propagate a 'model'-sharded layout onto the
# (unconstrained) output state even though the committed outer-state layout
# drops 'model' on TP-unfriendly archs — a layout mismatch that silently
# broke donation of the round/superstep outer state (the 16x16 production
# mesh hit exactly this). The plan fns now pin their outputs with
# with_sharding_constraint, so this config must alias like any other.
nopod = make_debug_mesh(data=2, model=4)
cfg = reduce_config(get_config("smollm-135m"))
plans = build_plans(cfg, "train_4k", nopod,
                   dcfg=DiLoCoConfig(n_workers=1, sync_interval=4))
for plan in plans:
    with nopod:
        c = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                    donate_argnums=plan.donate).lower(*plan.args).compile()
    rec = {"ok": True}
    if plan.name in ("round_step", "superstep"):
        from repro.launch.dryrun import round_step_donation_report
        rec["donation"] = round_step_donation_report(
            plan.args[0], c.as_text(), c.memory_analysis(),
            nopod.devices.size)
    out[f"nopod/{plan.name}"] = rec
print(json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_on_8_device_world():
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert len(out) == 10  # 6 combo plans + 4 no-pod train plans
    # the DiLoCo sync step must exist and every plan lowered
    assert all(v["ok"] for v in out.values())
    # the train step moves bytes over the wire (FSDP gathers)
    assert out["smollm-135m/train_4k/train_step"]["collective_total"] > 0
    # the engine's fused round + scan-over-R superstep plans lower on the
    # same mesh and communicate
    for plan in ("round_step", "superstep"):
        rec = out[f"smollm-135m/train_4k/{plan}"]
        assert rec["collective_total"] > 0
        # donated under GSPMD (ROADMAP open item): the outer-transform
        # state buffers are among the aliased outputs, and the per-chip
        # aliased bytes cover at least the outer params+opt shard
        donation = rec["donation"]
        assert donation["outer_opt_bytes_global"] > 0
        assert donation["outer_state_aliased"], donation
    # the no-pod (K=1, model > data) mesh is the configuration where GSPMD
    # output-sharding propagation used to break outer-state donation — it
    # must stay fully aliased now that the plan fns pin their outputs
    for plan in ("round_step", "superstep"):
        donation = out[f"nopod/{plan}"]["donation"]
        assert donation["outer_state_aliased"], donation
