"""Whole-run single-dispatch execution: one donated device program for the
entire training span, with checkpoints emitted from inside the program via
io_callback. Pins (a) bitwise equality of the whole-run dispatch against
sequential supersteps, (b) byte-identical checkpoints between the in-program
and host-side emission paths, (c) the driver telemetry's dispatch count, and
(d) the "auto" dispatch cost model."""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import save_checkpoint
from repro.core import DiLoCoConfig
from repro.data import DataConfig, MarkovStream, batches_for_round, batches_for_span
from repro.engine import TrainEngine, run_rounds
from repro.engine.superstep import auto_rounds_per_dispatch, effective_rounds_per_dispatch
from repro.models import ModelConfig, build_model
from repro.optim import OptimizerConfig

CFG = ModelConfig(arch_type="dense", n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, remat=False,
                  dtype="float32", qk_norm=True)
ICFG = OptimizerConfig(lr=1e-2, weight_decay=0.0)
H, K = 3, 2


def _stream(seed=3):
    return MarkovStream(DataConfig(vocab=CFG.vocab, seq_len=16,
                                   batch_per_worker=2, n_workers=K, seed=seed))


def _fresh():
    model = build_model(CFG)
    dcfg = DiLoCoConfig(n_workers=K, sync_interval=H, inner_name="muon")
    engine = TrainEngine(model, dcfg, ICFG)
    return engine, engine.init(jax.random.PRNGKey(0))


def _run(rounds, rounds_per_dispatch, *, checkpoint_in_program=False,
         on_state=None, on_state_every=0, seed=3):
    engine, state = _fresh()
    stream = _stream(seed)
    telemetry = {}
    state, history = run_rounds(
        engine, state, lambda r: batches_for_round(stream, r, H), rounds,
        rounds_per_dispatch=rounds_per_dispatch,
        span_batches_for=lambda r0, n: batches_for_span(stream, r0, H, n),
        on_state=on_state, on_state_every=on_state_every,
        checkpoint_in_program=checkpoint_in_program, telemetry=telemetry)
    return state, history, telemetry


def _leaves(state):
    return [np.asarray(x) for x in jax.tree.leaves(
        {"p": state["outer_params"], "u": state["outer_opt"],
         "round": state["round"]})]


# ---------------------------------------------------------------------------
# whole run == sequential supersteps, bit for bit, in ONE dispatch
# ---------------------------------------------------------------------------


def test_whole_run_single_dispatch_matches_sequential_bitwise():
    rounds = 6
    ref_state, ref_hist, ref_tel = _run(rounds, 2)
    one_state, one_hist, one_tel = _run(rounds, "auto")
    assert ref_tel["dispatches"] == 3
    assert one_tel["dispatches"] == 1
    assert one_tel["rounds_per_dispatch"] == rounds
    for a, b in zip(_leaves(ref_state), _leaves(one_state)):
        np.testing.assert_array_equal(a, b)
    # per-round metric records are identical too
    assert len(ref_hist) == len(one_hist) == rounds
    for ra, rb in zip(ref_hist, one_hist):
        assert ra == rb


# ---------------------------------------------------------------------------
# in-program (io_callback) checkpoints == host-side checkpoints, byte for byte
# ---------------------------------------------------------------------------


def test_in_program_checkpoint_bytes_identical_to_host_path(tmp_path):
    rounds, every = 4, 2

    def saves(sub, **kw):
        d = tmp_path / sub
        os.makedirs(d)
        seen = []

        def on_state(r, st):
            path = str(d / f"ckpt_{r}.npz")
            save_checkpoint(path, st, step=r + 1)
            seen.append(path)

        state, _, tel = _run(rounds, "auto", on_state=on_state,
                             on_state_every=every, **kw)
        return state, seen, tel

    host_state, host_ckpts, host_tel = saves("host")
    prog_state, prog_ckpts, prog_tel = saves("prog", checkpoint_in_program=True)
    # host path: the cadence clamps auto down to R=2 (2 dispatches); the
    # in-program path keeps the whole run in ONE dispatch
    assert host_tel["dispatches"] == 2 and not host_tel["in_program_checkpoints"]
    assert prog_tel["dispatches"] == 1 and prog_tel["in_program_checkpoints"]
    assert [os.path.basename(p) for p in host_ckpts] == \
           [os.path.basename(p) for p in prog_ckpts] == \
           ["ckpt_1.npz", "ckpt_3.npz"]
    for a, b in zip(host_ckpts, prog_ckpts):
        za, zb = np.load(a), np.load(b)
        assert sorted(za.files) == sorted(zb.files)
        for k in za.files:
            np.testing.assert_array_equal(za[k], zb[k], err_msg=k)
    # and the two runs end in the identical final state
    for a, b in zip(_leaves(host_state), _leaves(prog_state)):
        np.testing.assert_array_equal(a, b)


def test_in_program_checkpoint_cadence_need_not_divide_run(tmp_path):
    """5 rounds, checkpoint every 2: impossible for a single host-side
    dispatch (R must divide the cadence), routine for the io_callback path."""
    rounds, every = 5, 2
    got = []

    def on_state(r, st):
        got.append((r, int(np.asarray(st["round"]))))

    _, _, tel = _run(rounds, "auto", on_state=on_state, on_state_every=every,
                     checkpoint_in_program=True)
    assert tel["dispatches"] == 1 and tel["rounds_per_dispatch"] == rounds
    assert got == [(1, 2), (3, 4)]  # rounds 2 and 4 completed


def test_ckpt_flags_require_sink():
    engine, state = _fresh()
    stream = _stream()
    batches = batches_for_span(stream, 0, H, 2)
    with pytest.raises(ValueError, match="checkpoint_cb"):
        from repro.engine.superstep import build_superstep_fn

        fn = build_superstep_fn(lambda s, b: (s, {"loss": s["round"]}))
        fn(state, batches, ckpt_flags=np.array([True, False]))


# ---------------------------------------------------------------------------
# the "auto" dispatch cost model
# ---------------------------------------------------------------------------


def test_auto_rounds_unmeasured_is_whole_run():
    assert auto_rounds_per_dispatch(12) == 12
    assert auto_rounds_per_dispatch(1) == 1
    assert auto_rounds_per_dispatch(0) == 0 or auto_rounds_per_dispatch(0) == 1


def test_auto_rounds_cost_model_picks_smallest_amortizing_divisor():
    # overhead 1ms, round 50ms, 1% budget -> need R >= 2; smallest divisor
    # of 12 that is >= 2 is 2
    assert auto_rounds_per_dispatch(12, 0.001, 0.05) == 2
    # overhead 10ms, round 20ms -> need R >= 50 -> whole span (no divisor)
    assert auto_rounds_per_dispatch(12, 0.010, 0.020) == 12
    # generous budget: overhead amortized at R=1 already
    assert auto_rounds_per_dispatch(12, 0.0001, 0.05) == 1


def test_effective_rounds_auto_respects_cadence_clamps():
    # auto (unmeasured) = whole span, then gcd with the checkpoint cadence
    assert effective_rounds_per_dispatch("auto", 12, checkpoint_every=4) == 4
    assert effective_rounds_per_dispatch("auto", 12, checkpoint_every=0) == 12
    # measured: the cost model's choice still gets clamped
    assert effective_rounds_per_dispatch(
        "auto", 12, checkpoint_every=3, host_overhead_s=0.01,
        device_round_s=0.02) == 3
