"""End-to-end behaviour tests: the paper's qualitative claims at toy scale."""
import functools

import jax
import pytest

from repro.core import (
    DiLoCoConfig,
    diloco_init,
    diloco_round,
    make_optimizer,
    make_streaming_masks,
)
from repro.data import DataConfig, MarkovStream, batches_for_round
from repro.models import ModelConfig, build_model
from repro.optim import OptimizerConfig

CFG = ModelConfig(arch_type="dense", n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
                  d_ff=96, vocab=128, remat=False, dtype="float32")


def _train(dcfg, rounds=5, lr=None, seed=0):
    model = build_model(CFG)
    lr = lr or (2e-2 if dcfg.inner_name == "muon" else 4e-3)
    icfg = OptimizerConfig(lr=lr, weight_decay=0.0)
    opt = make_optimizer(dcfg, icfg)
    state = diloco_init(model, dcfg, icfg, jax.random.PRNGKey(seed))
    masks = make_streaming_masks(state, dcfg)
    stream = MarkovStream(DataConfig(vocab=CFG.vocab, seq_len=32, batch_per_worker=4,
                                     n_workers=dcfg.n_workers, seed=1))
    fn = jax.jit(functools.partial(diloco_round, model, dcfg, opt, masks=masks))
    last = None
    for r in range(rounds):
        state, info = fn(state, batches_for_round(stream, r, dcfg.sync_interval))
        last = float(info["loss"].mean())
    return last


@pytest.mark.slow
def test_muloco_beats_diloco_at_toy_scale():
    """Paper Finding 1 (absolute terms), qualitative at toy scale."""
    muloco = _train(DiLoCoConfig(n_workers=4, sync_interval=4, inner_name="muon"))
    diloco = _train(DiLoCoConfig(n_workers=4, sync_interval=4, inner_name="adamw"))
    assert muloco < diloco


@pytest.mark.slow
def test_loss_decreases_for_all_variants():
    from repro.core.compression import CompressionConfig

    variants = [
        DiLoCoConfig(n_workers=2, sync_interval=4, inner_name="muon"),
        DiLoCoConfig(n_workers=2, sync_interval=4, inner_name="adamw"),
        DiLoCoConfig(n_workers=2, sync_interval=4, inner_name="muon",
                     streaming_partitions=2),
        DiLoCoConfig(n_workers=2, sync_interval=4, inner_name="muon",
                     compression=CompressionConfig(kind="quant", bits=4,
                                                   error_feedback=True)),
    ]
    import numpy as np
    for dcfg in variants:
        first = _train(dcfg, rounds=1)
        last = _train(dcfg, rounds=5)
        assert np.isfinite(last) and last < first, dcfg
