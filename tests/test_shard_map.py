"""shard_map kernel partitioning: multi-device == single-device, bitwise.

Every Pallas call site routes through ``kernel_partitioning`` /
``kernel_specs`` on a mesh (the PR's tentpole). These tests assert the
contract that makes the routing deployable: for every kernel, the
shard_mapped multi-device output is **bitwise identical** to the
single-device Pallas path (and allclose to the jnp oracle), including the
flash custom VJP under the production composition
``vmap(spmd_axis_name='pod')`` + ``lax.scan`` + ``remat``, and the paged
decode kernel over a ragged page table.

The device world is forced to 8 host devices in a child process
(``tests/_shard_map_harness.py``) because XLA pins the device count at
first initialization — the main pytest process must keep its single CPU
device. The harness runs ALL kernels in one child (one jax init, not
seven) and prints a JSON verdict per kernel.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HARNESS = os.path.join(REPO, "tests", "_shard_map_harness.py")


@pytest.fixture(scope="module")
def verdicts() -> dict:
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run([sys.executable, HARNESS], capture_output=True,
                         text=True, env=env, cwd=REPO, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


def test_harness_world(verdicts):
    assert verdicts["devices"] == 8
    assert verdicts["mesh"] == {"pod": 2, "data": 2, "model": 2}


@pytest.mark.parametrize("kernel", [
    "flash_fwd", "quantize", "dequantize", "ns_orthogonalize",
    "outer_update", "paged_decode",
])
def test_shard_mapped_bitwise_and_close_to_ref(verdicts, kernel):
    rec = verdicts[kernel]
    assert rec["bitwise"], f"{kernel}: shard_mapped != single-device: {rec}"
    assert rec["vs_ref"], f"{kernel}: pallas path diverged from oracle: {rec}"


def test_flash_vjp_bitwise_under_vmap_scan_remat(verdicts):
    rec = verdicts["flash_vjp"]
    assert rec["bitwise"], f"flash VJP grads not bitwise: {rec}"
