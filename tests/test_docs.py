"""Docs <-> CLI consistency: every flag named in README/docs must exist in
an argparse parser, and every user-facing parser flag must be documented.

The parsers are collected in a subprocess because importing
``repro.launch.dryrun`` mutates ``XLA_FLAGS`` at module import (it must
precede jax backend init for the 512-device dry-run) — the main pytest
process keeps its environment untouched.
"""
import json
import os
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = ["README.md", "docs/architecture.md", "docs/transforms.md",
             "docs/benchmarks.md"]

# flags that belong to external tools (XLA, ruff), not to our parsers
EXTERNAL_PREFIXES = ("--xla", "--select")

_COLLECT = r"""
import json
from repro.launch.train import build_parser as train_parser
from repro.launch.dryrun import build_parser as dryrun_parser
from repro.launch.serve import build_parser as serve_parser
from benchmarks.run import build_parser as bench_parser
from benchmarks.check_regression import build_parser as regression_parser
from repro.kernels.autotune import build_parser as autotune_parser

out = {}
for name, build in [("train", train_parser), ("dryrun", dryrun_parser),
                    ("serve", serve_parser), ("benchmarks", bench_parser),
                    ("check_regression", regression_parser),
                    ("autotune", autotune_parser)]:
    flags = set()
    for action in build()._actions:
        flags.update(o for o in action.option_strings if o.startswith("--"))
    flags.discard("--help")
    out[name] = sorted(flags)
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def parser_flags() -> dict[str, set[str]]:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    res = subprocess.run([sys.executable, "-c", _COLLECT], capture_output=True,
                         text=True, env=env, cwd=REPO)
    assert res.returncode == 0, res.stderr[-3000:]
    raw = json.loads(res.stdout.strip().splitlines()[-1])
    return {k: set(v) for k, v in raw.items()}


def _doc_flags() -> dict[str, set[str]]:
    """--flag tokens per doc file (= signed both in prose and code blocks)."""
    found = {}
    for rel in DOC_FILES:
        path = os.path.join(REPO, rel)
        assert os.path.exists(path), f"{rel} is missing"
        with open(path) as f:
            text = f.read()
        flags = set(re.findall(r"(?<![\w-])--[a-z][a-z0-9-]*", text))
        found[rel] = {f for f in flags
                      if not f.startswith(EXTERNAL_PREFIXES)}
    return found


def test_every_documented_flag_exists(parser_flags):
    """No doc may name a CLI flag that no parser defines (docs can't rot)."""
    known = set().union(*parser_flags.values())
    for rel, flags in _doc_flags().items():
        unknown = flags - known
        assert not unknown, (
            f"{rel} names flags missing from every argparse parser: "
            f"{sorted(unknown)}")


def test_every_user_facing_flag_is_documented(parser_flags):
    """Every flag of the user-facing CLIs (train / dryrun / serve /
    benchmark runner) must appear in README or docs/."""
    documented = set().union(*_doc_flags().values())
    for cli, flags in parser_flags.items():
        missing = flags - documented
        assert not missing, (
            f"{cli} CLI flags undocumented in README/docs: {sorted(missing)}")


def test_reference_losses_documented():
    """The behavior-preservation reference values must match the pinned
    parity-test constants wherever they are quoted."""
    from test_parity import REFERENCE

    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    for inner, loss in REFERENCE.items():
        assert f"{loss:.4f}" in readme, (
            f"README does not quote the pinned {inner} reference loss {loss}")
