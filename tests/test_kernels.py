"""Per-kernel shape/dtype sweeps against the ref.py pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (200, 300, 130), (64, 512, 96), (1, 128, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_epilogue(m, k, n, dtype):
    key = jax.random.PRNGKey(m * 1000 + k + n)
    a = jax.random.normal(key, (m, k), dtype)
    b = jax.random.normal(jax.random.fold_in(key, 1), (k, n), dtype)
    d = jax.random.normal(jax.random.fold_in(key, 2), (m, n), dtype)
    out = ops.matmul(a, b, d, alpha=1.5, beta=-0.25)
    exp = ref.matmul_epilogue_ref(a, b, d, alpha=1.5, beta=-0.25)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(exp, np.float32),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("shape", [(64, 64), (96, 160), (160, 96), (3, 48, 32)])
def test_ns_orthogonalize_vs_ref(shape):
    g = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
    out = ops.ns_orthogonalize(g)
    exp = ref.ns_orthogonalize_ref(g)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(exp, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_ns_singular_value_band():
    """NS output singular values land in the quintic's convergence band."""
    g = jax.random.normal(jax.random.PRNGKey(1), (64, 256), jnp.float32)
    o = ops.ns_orthogonalize(g).astype(jnp.float32)
    s = jnp.linalg.svd(o, compute_uv=False)
    assert float(s.min()) > 0.3 and float(s.max()) < 1.6


@pytest.mark.parametrize("m,n", [(8, 128), (37, 257), (16, 16), (1, 64)])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_rowwise_quantize(m, n, bits):
    x = jax.random.normal(jax.random.PRNGKey(m + n + bits), (m, n), jnp.float32) * 3
    deq, codes, lo, scale = ops.quantize_rowwise(x, bits=bits)
    deq2, codes2, lo2, scale2 = ref.rowwise_quantize_ref(x, bits)
    # fp round-ties may flip isolated entries by one level between the kernel
    # and the oracle; require <0.2% such entries and everything else exact.
    diff = np.abs(np.asarray(deq) - np.asarray(deq2))
    level = np.asarray((jnp.max(x, 1, keepdims=True) - jnp.min(x, 1, keepdims=True))) / ((1 << bits) - 1)
    assert (diff > 1e-5).mean() < 0.002
    assert bool((diff <= level * 1.01 + 1e-6).all())
    assert float(jnp.mean((codes != codes2).astype(jnp.float32))) < 0.002
    # reconstruction error bounded by half a level per entry
    nlevels = (1 << bits) - 1
    err = jnp.abs(deq - x)
    bound = (jnp.max(x, axis=1, keepdims=True) - jnp.min(x, axis=1, keepdims=True)) / nlevels
    assert bool(jnp.all(err <= bound * 0.5 + 1e-6))


@pytest.mark.parametrize("shape", [(13, 77), (1024,), (3, 5, 7)])
def test_fused_nesterov(shape):
    key = jax.random.PRNGKey(7)
    th = jax.random.normal(key, shape)
    ps = jax.random.normal(jax.random.fold_in(key, 1), shape)
    u = jax.random.normal(jax.random.fold_in(key, 2), shape)
    t1, u1 = ops.nesterov_update(th, ps, u, lr=0.7, momentum=0.9)
    t2, u2 = ref.nesterov_update_ref(th, ps, u, lr=0.7, momentum=0.9)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(u1), np.asarray(u2), rtol=1e-6, atol=1e-6)


def test_pallas_ns_inside_muon_step():
    """ns_impl='pallas' is usable as the Muon backend end to end."""
    from repro.optim import OptimizerConfig, muon

    params = {"w": jnp.ones((24, 40)), "embed": jnp.ones((8, 4))}
    opt = muon(OptimizerConfig(lr=1e-2), ns_impl="pallas")
    st = opt.init(params)
    g = jax.tree.map(lambda p: p * 0.1, params)
    p2, _ = jax.jit(opt.step)(params, g, st)
    assert np.isfinite(np.asarray(p2["w"])).all()
