"""Pseudogradient analysis (paper §4.2, Figs. 2-5): measure alignment,
interference gap, step-norm stability and the Prop. 4.2 identity on live
MuLoCo/DiLoCo runs.

    PYTHONPATH=src python examples/pseudogradient_analysis.py
"""
import sys

sys.path.insert(0, ".")  # for benchmarks.*

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import collect_pseudogradients  # noqa: E402
from repro.core.analysis import (  # noqa: E402
    interference_gap,
    per_matrix_cosines,
    prop42_nuclear_identity,
)

K = 4
print(f"=== branching {K} workers from a warmed-up checkpoint (H=8) ===\n")
for inner in ("muon", "adamw"):
    deltas, psi_k, psi_1, steps = collect_pseudogradients(inner, K, track_steps=True)
    cos = per_matrix_cosines(psi_k, psi_1)
    vals = np.array(list(cos.values()))
    w = deltas["layers"]["mlp"]["w_in"]
    gap = float(interference_gap(w[:, 0], s_frac=0.25))
    sn = steps["mlp"]["w_in"]
    norms = jnp.sqrt(jnp.sum(sn ** 2, axis=(-2, -1)))
    cv = float((jnp.std(norms, axis=(0, 1)) / jnp.mean(norms, axis=(0, 1))).mean())
    name = "MuLoCo(muon)" if inner == "muon" else "DiLoCo(adamw)"
    print(f"{name}")
    print(f"  cosine(psi_K, psi_1):   mean={vals.mean():.4f}  spread={vals.std():.4f}")
    print(f"  top-25% interference:   {gap:.4f}")
    print(f"  step-norm CV (workers): {cv:.4f}   <- Muon's orthonormal steps")
    lhs, rhs = prop42_nuclear_identity(sn[:, :, 0], jnp.ones((sn.shape[1],)))
    print(f"  Prop 4.2 identity:      |Psi|_* = {float(lhs):.4f} == rhs {float(rhs):.4f}\n")
