"""Quickstart: MuLoCo vs DiLoCo in ~40 lines using the unified TrainEngine.

The engine compiles communication rounds (H inner steps + outer sync each)
into one donated, jitted superstep — below, the WHOLE run is a single
device dispatch: batches arrive round-stacked [R, H, K, B, S] and per-round
losses come back in one [R, H] buffer.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import DiLoCoConfig
from repro.data import DataConfig, MarkovStream, batches_for_span
from repro.engine import TrainEngine
from repro.models import ModelConfig, build_model
from repro.optim import OptimizerConfig

# a tiny Gemma3-style LM (the paper's architecture family)
cfg = ModelConfig(arch_type="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab=256, qk_norm=True, post_norm=True,
                  remat=False, dtype="float32")
model = build_model(cfg)

K, H, ROUNDS = 4, 6, 6  # workers, sync interval, communication rounds

for inner, lr in (("muon", 2e-2), ("adamw", 4e-3)):
    dcfg = DiLoCoConfig(n_workers=K, sync_interval=H, inner_name=inner,
                        outer_lr=0.7, outer_momentum=0.9)
    icfg = OptimizerConfig(lr=lr, weight_decay=1e-4)
    engine = TrainEngine(model, dcfg, icfg)
    state = engine.init(jax.random.PRNGKey(0))
    data = MarkovStream(DataConfig(vocab=cfg.vocab, seq_len=64, batch_per_worker=8,
                                   n_workers=K, seed=1))
    # all ROUNDS rounds in ONE dispatch; loss comes back [ROUNDS, H]
    state, out = engine.superstep(state, batches_for_span(data, 0, H, ROUNDS))
    name = "MuLoCo" if inner == "muon" else "DiLoCo"
    print(f"{name}: final train loss after {ROUNDS} rounds "
          f"({ROUNDS * H} inner steps, {ROUNDS} communications, 1 dispatch): "
          f"{float(out['loss'][-1, -1]):.4f}")
