"""Serve small models through both engines: the paged-KV continuous-batching
engine (dense attention families) and the dense-cache baseline (recurrent
families, which keep per-step state instead of a KV cache).

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.launch.serve import generate
from repro.models import build_model
from repro.serving import PagedEngine, Request

for arch in ("smollm-135m", "mamba2-370m", "zamba2-2.7b"):
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    prompts = jax.random.randint(rng, (4, 8), 0, cfg.vocab)  # 4 concurrent requests

    if model.supports_paged_decode:
        engine = PagedEngine(model, params, slots=2, page_size=8, max_pages=32,
                             decode_steps_per_dispatch=4, temperature=0.8, rng=rng)
        # stagger arrivals: two requests join mid-flight (continuous batching)
        reqs = [Request(f"r{i}", tuple(int(t) for t in row), 16, arrival=i)
                for i, row in enumerate(np.asarray(prompts))]
        out = engine.run(reqs)
        print(f"{arch:14s} ({cfg.arch_type}, paged): "
              f"{ {r: len(t) for r, t in out.items()} } tokens, "
              f"sample={out['r0'][:8].tolist()}")
    else:
        toks = generate(model, params, prompts, max_new=16, temperature=0.8, rng=rng)
        print(f"{arch:14s} ({cfg.arch_type}, naive): generated {toks.shape}, "
              f"sample={toks[0, 8:16].tolist()}")
