"""Serve a small model with batched requests through the decode path
(prefill + sampled generation against a shared KV cache).

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax

from repro.configs import get_config, reduce_config
from repro.launch.serve import generate
from repro.models import build_model

for arch in ("smollm-135m", "mamba2-370m", "zamba2-2.7b"):
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    prompts = jax.random.randint(rng, (4, 8), 0, cfg.vocab)  # 4 concurrent requests
    toks = generate(model, params, prompts, max_new=16, temperature=0.8, rng=rng)
    print(f"{arch:14s} ({cfg.arch_type}): generated {toks.shape}, "
          f"sample={toks[0, 8:16].tolist()}")
