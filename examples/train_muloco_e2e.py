"""End-to-end driver: train a ~small LM with MuLoCo for a few hundred steps,
with cosine schedule, eval logging, checkpointing and resume — the full
production path via repro.launch.train, which executes rounds through the
unified TrainEngine in supersteps (here 5 rounds per donated, jitted
dispatch, eval folded in + async metrics drain). Crash-safe by default:
checkpoints are round-stamped, checksummed, and fsync'd, so killing this
script at any point and re-running it with --resume auto continues from
the newest valid checkpoint with a byte-identical metrics trail; the
health sentinel rolls back and skips any round that goes non-finite.

    PYTHONPATH=src python examples/train_muloco_e2e.py
"""
from repro.launch.train import build_parser, train

args = build_parser().parse_args([
    "--arch", "smollm-135m",       # assigned architecture, reduced variant
    "--reduced",
    "--inner", "muon",             # MuLoCo
    "--workers", "4",
    "--sync-interval", "10",
    "--rounds", "25",              # 250 inner steps
    "--rounds-per-dispatch", "5",  # superstep: 5 rounds per device dispatch
    "--seq-len", "64",
    "--batch-per-worker", "8",
    "--lr", "2e-2",
    "--schedule", "cosine",
    "--checkpoint-every", "10",
    "--keep-checkpoints", "2",     # ckpt_<round>.npz retention + LATEST
    "--health-sentinel", "on",     # rollback-on-NaN/spike insurance
    "--resume", "auto",            # idempotent: re-running continues the run
    "--out", "results/example_muloco",
    "--verbose",
])
out = train(args)
print(f"trained to smoothed eval loss {out['final_loss']:.4f}; "
      f"checkpoints + metrics.csv in results/example_muloco/")
