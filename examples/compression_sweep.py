"""Communication-compression sweep (paper §6.3): quantization bits x mode x
error feedback, plus the wire-byte accounting used for the bandwidth model.

    PYTHONPATH=src python examples/compression_sweep.py
"""
import jax

from repro.core import CompressionConfig, DiLoCoConfig
from repro.core.collectives import collective_bytes_tree
from repro.data import DataConfig, MarkovStream, batches_for_round
from repro.engine import TrainEngine
from repro.models import ModelConfig, build_model
from repro.optim import OptimizerConfig

cfg = ModelConfig(arch_type="dense", n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
                  d_ff=96, vocab=128, remat=False, dtype="float32")
model = build_model(cfg)
K, H, ROUNDS = 2, 4, 6

def run(comp: CompressionConfig) -> float:
    dcfg = DiLoCoConfig(n_workers=K, sync_interval=H, inner_name="muon", compression=comp)
    engine = TrainEngine(model, dcfg, OptimizerConfig(lr=2e-2))
    state = engine.init(jax.random.PRNGKey(0))
    data = MarkovStream(DataConfig(vocab=cfg.vocab, seq_len=32, batch_per_worker=8,
                                   n_workers=K, seed=1))
    for r in range(ROUNDS):
        state, info = engine.step(state, batches_for_round(data, r, H))
    return float(info["loss"][-1])


params = build_model(cfg).init(jax.random.PRNGKey(0))
print(f"{'config':38s} {'loss':>8s} {'wire bytes/sync':>16s}")
for comp in [
    CompressionConfig(kind="none"),
    CompressionConfig(kind="quant", bits=8, quant_mode="linear"),
    CompressionConfig(kind="quant", bits=4, quant_mode="linear"),
    CompressionConfig(kind="quant", bits=4, quant_mode="linear", rowwise=True),
    CompressionConfig(kind="quant", bits=2, quant_mode="linear", error_feedback=True),
    CompressionConfig(kind="quant", bits=2, quant_mode="statistical", error_feedback=True),
    CompressionConfig(kind="topk", topk_frac=0.1, error_feedback=True, collective="gather"),
]:
    label = f"{comp.kind}/{comp.quant_mode if comp.kind == 'quant' else ''}" \
            f"{comp.bits if comp.kind == 'quant' else comp.topk_frac}" \
            f"{'/rw' if comp.rowwise else ''}{'/EF' if comp.error_feedback else ''}"
    loss = run(comp)
    wire = collective_bytes_tree(params, comp, K)["bytes_per_sync_per_worker"]
    print(f"{label:38s} {loss:8.4f} {wire:16,d}")
