"""Communication-compression sweep (paper §6.3): quantization bits x mode x
error feedback, with both wire-byte accountings — *measured* (the actual
wire buffers the engine's collective moves: packed codes + row metadata +
indices; also reported per round by the engine as ``comm_bytes``) and the
closed-form *model* used by the bandwidth estimates.

    PYTHONPATH=src python examples/compression_sweep.py
"""
import jax
import jax.numpy as jnp

from repro.core import CompressionConfig, DiLoCoConfig
from repro.core.collectives import collective_bytes_tree, measured_sync_bytes
from repro.data import DataConfig, MarkovStream, batches_for_round
from repro.engine import TrainEngine
from repro.models import ModelConfig, build_model
from repro.optim import OptimizerConfig

cfg = ModelConfig(arch_type="dense", n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
                  d_ff=96, vocab=128, remat=False, dtype="float32")
model = build_model(cfg)
K, H, ROUNDS = 2, 4, 6

def run(comp: CompressionConfig) -> tuple[float, float]:
    dcfg = DiLoCoConfig(n_workers=K, sync_interval=H, inner_name="muon", compression=comp)
    engine = TrainEngine(model, dcfg, OptimizerConfig(lr=2e-2))
    state = engine.init(jax.random.PRNGKey(0))
    data = MarkovStream(DataConfig(vocab=cfg.vocab, seq_len=32, batch_per_worker=8,
                                   n_workers=K, seed=1))
    for r in range(ROUNDS):
        state, info = engine.step(state, batches_for_round(data, r, H))
    # the engine reports each round's measured wire traffic
    return float(info["loss"][-1]), float(info["comm_bytes"])


params = build_model(cfg).init(jax.random.PRNGKey(0))
print(f"{'config':38s} {'loss':>8s} {'measured B/sync':>16s} {'modeled B/sync':>15s}")
for comp in [
    CompressionConfig(kind="none"),
    CompressionConfig(kind="quant", bits=8, quant_mode="linear"),
    CompressionConfig(kind="quant", bits=4, quant_mode="linear"),
    CompressionConfig(kind="quant", bits=4, quant_mode="linear", rowwise=True),
    CompressionConfig(kind="quant", bits=2, quant_mode="linear", error_feedback=True),
    CompressionConfig(kind="quant", bits=2, quant_mode="statistical", error_feedback=True),
    CompressionConfig(kind="topk", topk_frac=0.1, error_feedback=True, collective="gather"),
]:
    label = f"{comp.kind}/{comp.quant_mode if comp.kind == 'quant' else ''}" \
            f"{comp.bits if comp.kind == 'quant' else comp.topk_frac}" \
            f"{'/rw' if comp.rowwise else ''}{'/EF' if comp.error_feedback else ''}"
    loss, measured = run(comp)
    # engine metric == direct accounting (the metric travels as f32, so
    # compare at f32 precision — exact below ~16.7 MB/sync)
    assert measured == float(jnp.float32(measured_sync_bytes(params, comp, K)))
    modeled = collective_bytes_tree(params, comp, K)["bytes_per_sync_per_worker"]
    print(f"{label:38s} {loss:8.4f} {measured:16,.0f} {modeled:15,d}")
