"""Config registry: assigned architectures + the paper's scaling ladder.

Every architecture is selectable via ``--arch <id>`` in the launchers. Each
config cites its source in ``citation``. ``reduce_config`` produces the
CPU-smoke-test variant (<=2 layers / superblocks, d_model <= 512, <= 4
experts) of the same family; ``config_for_shape`` applies the per-input-shape
policy (e.g. sliding-window attention for dense archs on long_500k).
"""
from __future__ import annotations

import dataclasses

from repro.models.common import ModelConfig

# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

LONG_CONTEXT_WINDOW = 8_192  # sliding window used by dense archs on long_500k


def config_for_shape(cfg: ModelConfig, shape: str) -> ModelConfig:
    """Per-shape architecture policy (see DESIGN.md §4)."""
    if shape == "long_500k" and cfg.arch_type in ("dense", "moe", "vlm"):
        # dense-family archs run the 524k decode only via the sub-quadratic
        # sliding-window variant (the brief's carve-out).
        return cfg.replace(sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def shape_supported(cfg: ModelConfig, shape: str) -> bool:
    return shape not in cfg.skip_shapes


# ---------------------------------------------------------------------------
# Reduced smoke-test variants
# ---------------------------------------------------------------------------


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Same family, toy size: 2 layers/superblocks, d_model<=256, <=4 experts."""
    d = min(cfg.d_model, 256)
    heads = max(min(cfg.n_heads, 4), 1)
    kv = max(min(cfg.n_kv_heads, heads), 1)
    if heads % kv:
        kv = 1
    upd: dict = dict(
        d_model=d,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=d // heads,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab=min(cfg.vocab, 512),
        remat=False,
        dtype="float32",
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
    )
    if cfg.arch_type == "hybrid":
        upd.update(n_layers=4, hybrid_period=2, ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    elif cfg.arch_type == "ssm":
        upd.update(n_layers=2, ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    elif cfg.arch_type == "vlm":
        upd.update(n_layers=4, vlm_period=2, n_image_tokens=16)
    elif cfg.arch_type == "audio":
        upd.update(n_layers=2, n_encoder_layers=2, n_audio_frames=16)
    else:
        upd.update(n_layers=2)
    if cfg.n_experts:
        upd.update(n_experts=4, experts_per_token=2, n_shared_experts=min(cfg.n_shared_experts, 1))
    return cfg.replace(**upd)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    from repro.configs import (  # noqa: F401
        deepseek_moe_16b,
        kimi_k2_1t_a32b,
        llama_3_2_vision_90b,
        mamba2_370m,
        mistral_large_123b,
        moonshot_v1_16b_a3b,
        nemotron_4_15b,
        paper_gemma3,
        smollm_135m,
        whisper_large_v3,
        zamba2_2_7b,
    )

    _LOADED = True


ASSIGNED_ARCHS = (
    "mistral-large-123b",
    "mamba2-370m",
    "nemotron-4-15b",
    "kimi-k2-1t-a32b",
    "whisper-large-v3",
    "llama-3.2-vision-90b",
    "smollm-135m",
    "deepseek-moe-16b",
    "moonshot-v1-16b-a3b",
    "zamba2-2.7b",
)
