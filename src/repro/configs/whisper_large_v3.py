"""whisper-large-v3 [audio] — enc-dec, conv frontend stubbed, arXiv:2212.04356.

32 encoder + 32 decoder layers at d=1280 (model card); MHA (kv == heads).
long_500k is skipped: a 524k-token decode is not meaningful for the 30s /
448-token audio-decoder family (DESIGN.md §4).
"""
from repro.configs.base import register
from repro.models.common import ModelConfig

CONFIG = register(ModelConfig(
    name="whisper-large-v3",
    arch_type="audio",
    n_layers=32,           # decoder
    n_encoder_layers=32,   # encoder
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,  # MHA
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    activation="gelu",
    qk_norm=False,
    n_audio_frames=1500,
    skip_shapes=("long_500k",),
    citation="[arXiv:2212.04356]",
))
