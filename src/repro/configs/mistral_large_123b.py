"""mistral-large-123b [dense] — hf:mistralai/Mistral-Large-Instruct-2407."""
from repro.configs.base import register
from repro.models.common import ModelConfig

CONFIG = register(ModelConfig(
    name="mistral-large-123b",
    arch_type="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,  # GQA
    head_dim=128,
    d_ff=28672,
    vocab=32768,
    activation="swiglu",
    qk_norm=False,
    rope_theta=1_000_000.0,
    citation="[hf:mistralai/Mistral-Large-Instruct-2407]",
))
