"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8 + 1 shared.

[arXiv:2501.kimi2] (paper-table). Per-expert d_ff=2048 (fine-grained).
"""
from repro.configs.base import register
from repro.models.common import ModelConfig

CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,  # GQA
    head_dim=112,
    d_ff=2048,  # per routed expert
    vocab=163840,
    n_experts=384,
    experts_per_token=8,
    n_shared_experts=1,
    capacity_factor=1.25,
    citation="[arXiv:2501.kimi2]",
))
