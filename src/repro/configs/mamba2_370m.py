"""mamba2-370m [ssm] — SSD (state-space duality), arXiv:2405.21060."""
from repro.configs.base import register
from repro.models.common import ModelConfig

CONFIG = register(ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,  # attention-free
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,  # -> 32 SSD heads
    ssm_chunk=256,
    conv_width=4,
    citation="[arXiv:2405.21060]",
))
