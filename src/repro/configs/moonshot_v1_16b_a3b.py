"""moonshot-v1-16b-a3b — hf:moonshotai/Moonlight-16B-A3B.

Assignment tags this [dense] but specifies `MoE 64e top-6` fields and
Moonlight-16B-A3B *is* a DeepSeek-style MoE; implemented as MoE per its
fields (tag discrepancy noted in DESIGN.md §4).
"""
from repro.configs.base import register
from repro.models.common import ModelConfig

CONFIG = register(ModelConfig(
    name="moonshot-v1-16b-a3b",
    arch_type="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,  # per expert
    vocab=163840,
    n_experts=64,
    experts_per_token=6,
    n_shared_experts=2,
    citation="[hf:moonshotai/Moonlight-16B-A3B]",
))
