"""nemotron-4-15b [dense] — GQA + squared-ReLU FFN, arXiv:2402.16819."""
from repro.configs.base import register
from repro.models.common import ModelConfig

CONFIG = register(ModelConfig(
    name="nemotron-4-15b",
    arch_type="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,  # GQA
    head_dim=128,
    d_ff=24576,
    vocab=256000,
    activation="relu2",  # squared ReLU
    qk_norm=False,
    rope_theta=10_000.0,
    citation="[arXiv:2402.16819]",
))
