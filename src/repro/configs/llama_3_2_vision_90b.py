"""llama-3.2-vision-90b [vlm] — cross-attn image layers every 5th layer.

hf:meta-llama/Llama-3.2-11B-Vision (90B scale-up). ViT encoder + projector
stubbed; input_specs supplies patch embeddings [B, 1600, d].
"""
from repro.configs.base import register
from repro.models.common import ModelConfig

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,  # GQA
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    vlm_period=5,  # 20 gated cross-attn layers among 100
    n_image_tokens=1600,
    rope_theta=500_000.0,
    citation="[hf:meta-llama/Llama-3.2-11B-Vision]",
))
