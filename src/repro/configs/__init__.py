from repro.configs.base import (  # noqa: F401
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    InputShape,
    config_for_shape,
    get_config,
    list_configs,
    reduce_config,
    register,
    shape_supported,
)
