"""The paper's own Gemma3-style scaling ladder (Tab. 1).

SwiGLU FFNs, QK-norm, extra RMSNorm before residual connections (post-norms),
Llama3 tokenizer (vocab 128256), seq 2048. "QKV Dimension" = d_model,
"Hidden Dimension" = d_ff.
"""
from repro.configs.base import register
from repro.models.common import ModelConfig


def _ladder(name, n_layers, n_heads, d_model, d_ff):
    return register(ModelConfig(
        name=name,
        arch_type="dense",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_heads,
        head_dim=d_model // n_heads,
        d_ff=d_ff,
        vocab=128256,
        activation="swiglu",
        qk_norm=True,
        post_norm=True,
        citation="[paper Tab. 1, Gemma3-style / arXiv:2503.19786]",
    ))


PAPER_150M = _ladder("paper-150m", 6, 4, 512, 1408)
PAPER_416M = _ladder("paper-416m", 12, 8, 1024, 2816)
PAPER_914M = _ladder("paper-914m", 18, 12, 1536, 4224)
PAPER_1_76B = _ladder("paper-1.76b", 24, 16, 2048, 5632)
PAPER_3_07B = _ladder("paper-3.07b", 30, 20, 2560, 7040)
PAPER_15B = _ladder("paper-15.23b", 54, 36, 4608, 12672)
