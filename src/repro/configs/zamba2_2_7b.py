"""zamba2-2.7b [hybrid] — Mamba2 backbone + one shared attention block
invoked every 6 layers, arXiv:2411.15242."""
from repro.configs.base import register
from repro.models.common import ModelConfig

CONFIG = register(ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,  # shared attention block's MLP
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    hybrid_period=6,  # 9 superblocks of (shared attn + 6 mamba layers)
    sliding_window=4096,  # shared attn uses a window so long_500k stays sub-quadratic
    citation="[arXiv:2411.15242]",
))
