"""smollm-135m [dense] — llama-arch small, hf:HuggingFaceTB/SmolLM-135M."""
from repro.configs.base import register
from repro.models.common import ModelConfig

CONFIG = register(ModelConfig(
    name="smollm-135m",
    arch_type="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,  # GQA
    head_dim=64,
    d_ff=1536,
    vocab=49152,
    tie_embeddings=True,
    qk_norm=False,
    rope_theta=10_000.0,
    citation="[hf:HuggingFaceTB/SmolLM-135M]",
))
