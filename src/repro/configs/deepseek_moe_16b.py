"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained experts.

arXiv:2401.06066.
"""
from repro.configs.base import register
from repro.models.common import ModelConfig

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,  # per expert
    vocab=102400,
    n_experts=64,
    experts_per_token=6,
    n_shared_experts=2,
    rope_theta=10_000.0,
    citation="[arXiv:2401.06066]",
))
