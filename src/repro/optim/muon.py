"""Muon — the paper's MuLoCo inner optimizer.

Momentum accumulation followed by 5 quintic Newton–Schulz iterations that
orthogonalize each hidden weight-matrix update (Jordan et al., 2024
coefficients a,b,c = 3.4445, -4.7750, 2.0315), with decoupled weight decay
(important at scale per Liu et al., 2025). Per the paper, Muon is applied to
hidden matrices only; embeddings, norms, biases and the output head fall back
to AdamW inside the same optimizer step.

Stacked parameters from scan-over-layers ([L, m, n]) and MoE expert banks
([L, E, m, n]) are orthogonalized per-matrix via reshape+vmap.

``ns_impl='pallas'`` routes the Newton–Schulz matmuls through the Pallas TPU
kernel in ``repro.kernels`` (interpret-mode on CPU); ``'jnp'`` is the pure
XLA path used for dry-runs and production lowering.
"""
from __future__ import annotations

import math
import re
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import shard_hint
from repro.optim.base import Optimizer, OptimizerConfig, make_schedule
from repro.utils.tree import tree_map_with_path

PyTree = Any

NS_COEFFS = (3.4445, -4.7750, 2.0315)

# Parameters that never receive Muon (paper: embeddings, norms, output layer;
# we extend with SSM scalar/vector state and conv filters which are not plain
# matmul weights).
_ADAMW_PATTERN = re.compile(
    r"(embed|unembed|head|norm|bias|scale|dt_bias|a_log|d_skip|conv|rope|router_bias)",
    re.IGNORECASE,
)


def muon_label(path: str, leaf) -> str:
    """'muon' for hidden matmul matrices, 'adamw' otherwise."""
    if _ADAMW_PATTERN.search(path):
        return "adamw"
    shape = leaf.shape
    if len(shape) < 2 or shape[-1] < 2 or shape[-2] < 2:
        return "adamw"
    return "muon"


def param_labels(params: PyTree) -> PyTree:
    return tree_map_with_path(muon_label, params)


def _ns_body(X: jax.Array) -> jax.Array:
    """One quintic NS iteration on [..., m, n] (batched-safe)."""
    a, b, c = NS_COEFFS
    Xt = jnp.swapaxes(X, -1, -2)
    A = X @ Xt
    B = b * A + c * (A @ A)
    return a * X + B @ X


def newton_schulz(G: jax.Array, iters: int = 5, eps: float = 1e-7) -> jax.Array:
    """Orthogonalize the trailing two dims of G via quintic Newton–Schulz.

    Works on [m, n] and any stacked [..., m, n]. Computation in bf16 per the
    Muon reference (NS is robust to low precision), normalization in fp32.
    """
    orig_dtype = G.dtype
    *batch, m, n = G.shape
    X = G.reshape((-1, m, n)).astype(jnp.float32)
    transpose = m > n
    if transpose:
        X = jnp.swapaxes(X, -1, -2)
    norm = jnp.sqrt(jnp.sum(X * X, axis=(-2, -1), keepdims=True)) + eps
    X = (X / norm).astype(jnp.bfloat16)

    def body(X, _):
        return _ns_body(X), None

    X, _ = jax.lax.scan(body, X, None, length=iters)
    if transpose:
        X = jnp.swapaxes(X, -1, -2)
    return X.reshape((*batch, m, n)).astype(orig_dtype)


def newton_schulz_pallas(G: jax.Array, iters: int = 5, eps: float = 1e-7) -> jax.Array:
    """Same contract as :func:`newton_schulz` but with Pallas-kernel matmuls."""
    from repro.kernels.ops import ns_orthogonalize

    return ns_orthogonalize(G, iters=iters, eps=eps)


def _muon_lr_scale(shape: tuple[int, ...], mode: str) -> float:
    m, n = int(shape[-2]), int(shape[-1])
    if mode == "paper":  # paper §5: rescale lr by sqrt(n/m) for W in R^{m x n}
        return math.sqrt(n / m)
    if mode == "jordan":
        return max(1.0, m / n) ** 0.5
    if mode == "moonlight":
        return 0.2 * math.sqrt(max(m, n))
    if mode == "none":
        return 1.0
    raise ValueError(f"unknown muon lr scale mode {mode!r}")


def muon(cfg: OptimizerConfig, ns_impl: str = "jnp", adamw_lr_ratio: float = 1.0) -> Optimizer:
    """Muon for hidden matrices + AdamW for everything else (single step fn).

    ``adamw_lr_ratio`` scales the AdamW learning rate relative to the Muon lr
    (commonly tuned separately; paper tunes one inner lr, so default 1).
    """
    sched = make_schedule(cfg)
    ns_fn = newton_schulz_pallas if ns_impl == "pallas" else newton_schulz

    def init(params: PyTree) -> PyTree:
        labels = param_labels(params)
        sdt = jnp.dtype(cfg.state_dtype)
        m = jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params)
        # Second moment only materialized for AdamW-labelled leaves: Muon's
        # 3x-vs-4x memory advantage (paper Tab. 9) falls out of this.
        v = jax.tree.map(
            lambda p, lb: jnp.zeros(p.shape if lb == "adamw" else (1,), sdt),
            params,
            labels,
        )
        return {"m": m, "v": v, "count": jnp.zeros((), jnp.int32)}

    def step(params: PyTree, grads: PyTree, state: PyTree):
        labels = param_labels(params)
        count = state["count"] + 1
        lr = sched(count)
        b1, b2, eps, wd = cfg.b1, cfg.b2, cfg.eps, cfg.weight_decay
        bc1 = 1.0 - b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - b2 ** count.astype(jnp.float32)

        sdt = jnp.dtype(cfg.state_dtype)

        def upd(lb, p, g, m, v):
            g = g.astype(jnp.float32)
            p32 = p.astype(jnp.float32)
            if lb == "muon":
                m = b1 * m.astype(jnp.float32) + g  # paper: m_t = beta m_{t-1} + g_t
                # Layer-parallel Newton-Schulz: reshard the momentum so whole
                # matrices live on one chip (leading stacked axis -> mesh) and
                # the 5 NS iterations run with ZERO collectives; reshard the
                # orthogonalized result back. Without this, every NS matmul
                # psums an [m,m] partial product (measured: 6.1 TB/chip/step
                # on mistral-123b train_4k — EXPERIMENTS.md §Perf it.2).
                # No-op unless launch installs an "ns_matrix" rule.
                m_local = shard_hint(m, "ns_matrix")
                O = ns_fn(m_local, iters=cfg.ns_iters).astype(jnp.float32)
                O = shard_hint(O, "ns_out")
                scale = _muon_lr_scale(p.shape, cfg.muon_lr_scale_mode)
                new_p = p32 - (lr * scale) * O - lr * wd * p32
                return new_p.astype(p.dtype), m.astype(sdt), v
            # AdamW branch (embeddings/norms/head)
            m = b1 * m.astype(jnp.float32) + (1.0 - b1) * g
            v = b2 * v.astype(jnp.float32) + (1.0 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            alr = lr * adamw_lr_ratio
            new_p = p32 - alr * u - alr * wd * p32
            return new_p.astype(p.dtype), m.astype(sdt), v.astype(sdt)

        out = jax.tree.map(upd, labels, params, grads, state["m"], state["v"])
        is_tup = lambda t: isinstance(t, tuple)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is_tup)
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is_tup)
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is_tup)
        return new_params, {"m": new_m, "v": new_v, "count": count}

    return Optimizer(init=init, step=step)
