"""Muon — the paper's MuLoCo inner optimizer, as a transform chain.

Momentum accumulation followed by 5 quintic Newton–Schulz iterations that
orthogonalize each hidden weight-matrix update (Jordan et al., 2024
coefficients a,b,c = 3.4445, -4.7750, 2.0315), with decoupled weight decay
(important at scale per Liu et al., 2025). Per the paper, Muon is applied to
hidden matrices only; embeddings, norms, biases and the output head fall back
to AdamW — expressed as::

    partition(muon_label, {
        "muon":  chain(trace_momentum(cfg), orthogonalize(cfg, ns_impl)),
        "adamw": scale_by_adam(cfg),
    })

wrapped by :func:`repro.optim.base.descend` with the per-shape lr scale.
Variants (MuonBP, NorMuon) swap or extend the "muon" chain — see
:mod:`repro.optim.muon_variants`.

Stacked parameters from scan-over-layers ([L, m, n]) and MoE expert banks
([L, E, m, n]) are orthogonalized per-matrix via reshape+vmap.

``ns_impl='pallas'`` routes the Newton–Schulz matmuls through the Pallas TPU
kernel in ``repro.kernels`` (interpret-mode on CPU); ``'jnp'`` is the pure
XLA path used for dry-runs and production lowering.
"""
from __future__ import annotations

import math
import re
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import shard_hint
from repro.optim.adamw import scale_by_adam
from repro.optim.base import Optimizer, OptimizerConfig, descend
from repro.optim.transform import Transform, chain, partition

PyTree = Any

NS_COEFFS = (3.4445, -4.7750, 2.0315)

# Parameters that never receive Muon (paper: embeddings, norms, output layer;
# we extend with SSM scalar/vector state and conv filters which are not plain
# matmul weights).
_ADAMW_PATTERN = re.compile(
    r"(embed|unembed|head|norm|bias|scale|dt_bias|a_log|d_skip|conv|rope|router_bias)",
    re.IGNORECASE,
)


def muon_label(path: str, leaf) -> str:
    """'muon' for hidden matmul matrices, 'adamw' otherwise."""
    if _ADAMW_PATTERN.search(path):
        return "adamw"
    shape = leaf.shape
    if len(shape) < 2 or shape[-1] < 2 or shape[-2] < 2:
        return "adamw"
    return "muon"


def param_labels(params: PyTree) -> PyTree:
    from repro.utils.tree import tree_map_with_path

    return tree_map_with_path(muon_label, params)


def _ns_body(X: jax.Array) -> jax.Array:
    """One quintic NS iteration on [..., m, n] (batched-safe)."""
    a, b, c = NS_COEFFS
    Xt = jnp.swapaxes(X, -1, -2)
    A = X @ Xt
    B = b * A + c * (A @ A)
    return a * X + B @ X


def newton_schulz(G: jax.Array, iters: int = 5, eps: float = 1e-7) -> jax.Array:
    """Orthogonalize the trailing two dims of G via quintic Newton–Schulz.

    Works on [m, n] and any stacked [..., m, n]. Computation in bf16 per the
    Muon reference (NS is robust to low precision), normalization in fp32.
    """
    orig_dtype = G.dtype
    *batch, m, n = G.shape
    X = G.reshape((-1, m, n)).astype(jnp.float32)
    transpose = m > n
    if transpose:
        X = jnp.swapaxes(X, -1, -2)
    norm = jnp.sqrt(jnp.sum(X * X, axis=(-2, -1), keepdims=True)) + eps
    X = (X / norm).astype(jnp.bfloat16)

    def body(X, _):
        return _ns_body(X), None

    X, _ = jax.lax.scan(body, X, None, length=iters)
    if transpose:
        X = jnp.swapaxes(X, -1, -2)
    return X.reshape((*batch, m, n)).astype(orig_dtype)


def newton_schulz_pallas(G: jax.Array, iters: int = 5, eps: float = 1e-7) -> jax.Array:
    """Same contract as :func:`newton_schulz` but with Pallas-kernel matmuls."""
    from repro.kernels.ops import ns_orthogonalize

    return ns_orthogonalize(G, iters=iters, eps=eps)


def ns_fn_for(ns_impl: str):
    return newton_schulz_pallas if ns_impl == "pallas" else newton_schulz


def _muon_lr_scale(shape: tuple[int, ...], mode: str) -> float:
    m, n = int(shape[-2]), int(shape[-1])
    if mode == "paper":  # paper §5: rescale lr by sqrt(n/m) for W in R^{m x n}
        return math.sqrt(n / m)
    if mode == "jordan":
        return max(1.0, m / n) ** 0.5
    if mode == "moonlight":
        return 0.2 * math.sqrt(max(m, n))
    if mode == "none":
        return 1.0
    raise ValueError(f"unknown muon lr scale mode {mode!r}")


# ---------------------------------------------------------------------------
# The Muon transform stages
# ---------------------------------------------------------------------------


def trace_momentum(cfg: OptimizerConfig) -> Transform:
    """Muon momentum: m_t = beta * m_{t-1} + g_t (paper Alg. 1; note NO
    (1-beta) dampening — the raw gradient is added). Passes the fp32
    accumulator downstream, stores it in ``state_dtype``."""
    b1 = cfg.b1
    sdt = jnp.dtype(cfg.state_dtype)

    def init(tree: PyTree) -> PyTree:
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), tree)}

    def update(updates: PyTree, state: PyTree, params: PyTree):
        m = jax.tree.map(
            lambda g, m: b1 * m.astype(jnp.float32) + g.astype(jnp.float32),
            updates, state["m"])
        return m, {"m": jax.tree.map(lambda x: x.astype(sdt), m)}

    return Transform(init=init, update=update)


def orthogonalize(cfg: OptimizerConfig, ns_impl: str = "jnp") -> Transform:
    """Newton–Schulz orthogonalization of each [..., m, n] update.

    Layer-parallel resharding hints: the momentum is resharded so whole
    matrices live on one chip (leading stacked axis -> mesh) and the 5 NS
    iterations run with ZERO collectives; the orthogonalized result is
    resharded back. Without this, every NS matmul psums an [m,m] partial
    product (measured: 6.1 TB/chip/step on mistral-123b train_4k —
    EXPERIMENTS.md §Perf it.2). No-op unless launch installs an "ns_matrix"
    rule.
    """
    ns_fn = ns_fn_for(ns_impl)
    iters = cfg.ns_iters

    def orth(u, _params):
        def per_leaf(m):
            m_local = shard_hint(m, "ns_matrix")
            out = ns_fn(m_local, iters=iters).astype(jnp.float32)
            return shard_hint(out, "ns_out")

        return jax.tree.map(per_leaf, u)

    from repro.optim.transform import stateless

    return stateless(orth)


def muon_partition(cfg: OptimizerConfig, muon_chain: Transform) -> Transform:
    """``partition(muon_label, {muon: <chain>, adamw: scale_by_adam})``."""
    return partition(muon_label, {"muon": muon_chain,
                                  "adamw": scale_by_adam(cfg)})


def muon_mults(cfg: OptimizerConfig, adamw_lr_ratio: float = 1.0):
    """Per-leaf (update, decay) lr multipliers for the descent stage: hidden
    matrices get the shape-dependent Muon scale (decay stays at the base lr,
    matching the paper's decoupled decay); AdamW-fallback leaves get the
    optional lr ratio on both terms."""

    def mults(path: str, leaf) -> tuple[float, float]:
        if muon_label(path, leaf) == "muon":
            return _muon_lr_scale(leaf.shape, cfg.muon_lr_scale_mode), 1.0
        return adamw_lr_ratio, adamw_lr_ratio

    return mults


def muon(cfg: OptimizerConfig, ns_impl: str = "jnp", adamw_lr_ratio: float = 1.0) -> Optimizer:
    """Muon for hidden matrices + AdamW for everything else.

    ``adamw_lr_ratio`` scales the AdamW learning rate relative to the Muon lr
    (commonly tuned separately; paper tunes one inner lr, so default 1).
    """
    tx = muon_partition(cfg, chain(trace_momentum(cfg), orthogonalize(cfg, ns_impl)))
    return descend(tx, cfg, muon_mults(cfg, adamw_lr_ratio))
