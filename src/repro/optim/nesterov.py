"""Outer optimizers as terminal transforms over the pseudogradient.

:func:`nesterov` is exactly the paper's Eq. (3) / Algorithm 1 lines 12-13:

    u^(t)     = mu * u^(t-H) + eta_out * Psi^(t)
    theta^(t) = theta^(t-1) - mu * u^(t) - eta_out * Psi^(t)

where Psi is the averaged weight-space delta (pseudogradient). Note the
paper folds eta_out into the momentum accumulator (SlowMo-style), so the
effective step is mu*u + eta_out*Psi.

Both outer transforms are *terminal*: their ``update`` passes Psi through
unchanged (so the round executor can report it) and ``apply`` performs the
descent — either in pure XLA, or, with ``kernel=True``, through the fused
Pallas outer-update kernel (:mod:`repro.kernels.outer_update`), which
produces (theta', u') in one elementwise VMEM pass and halves the HBM
traffic of the sync step. ``mask_state`` implements the streaming
(partitioned) sync merge: untouched partitions keep their momentum.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.transform import Transform
from repro.utils.tree import tree_unzip

PyTree = Any


def nesterov(lr: float, momentum: float, *, state_dtype=jnp.float32,
             kernel: bool = False) -> Transform:
    """Outer SGD with Nesterov momentum; state ``{"u": tree}``.

    The momentum buffer keeps the dtype it was initialized with
    (``state_dtype``); math is fp32 (or inside the fused kernel)."""

    def init(params: PyTree) -> PyTree:
        return {"u": jax.tree.map(lambda p: jnp.zeros(p.shape, state_dtype), params)}

    def update(updates: PyTree, state: PyTree, params: PyTree):
        return updates, state

    def apply(params: PyTree, updates: PyTree, state: PyTree):
        if kernel:
            from repro.kernels.ops import nesterov_update

            def upd(p, psi, u):
                p_new, u_new = nesterov_update(p, psi, u, lr=lr, momentum=momentum)
                return p_new, u_new.astype(u.dtype)
        else:

            def upd(p, psi, u):
                psi = psi.astype(jnp.float32)
                u_new = momentum * u.astype(jnp.float32) + lr * psi
                p_new = p.astype(jnp.float32) - momentum * u_new - lr * psi
                return p_new.astype(p.dtype), u_new.astype(u.dtype)

        new_params, new_u = tree_unzip(
            jax.tree.map(upd, params, updates, state["u"]), 2)
        return new_params, {"u": new_u}

    def mask_state(mask: PyTree, new_state: PyTree, old_state: PyTree) -> PyTree:
        from repro.core.streaming import masked_update

        return {"u": masked_update(mask, new_state["u"], old_state["u"])}

    return Transform(init=init, update=update, apply=apply, mask_state=mask_state)


def outer_sgd(lr: float) -> Transform:
    """Plain outer SGD: theta' = theta - eta_out * Psi. Stateless."""

    def apply(params: PyTree, updates: PyTree, state: PyTree):
        new_params = jax.tree.map(
            lambda p, psi: (p.astype(jnp.float32) - lr * psi.astype(jnp.float32)
                            ).astype(p.dtype),
            params, updates)
        return new_params, state

    return Transform(init=lambda params: {},
                     update=lambda u, s, p: (u, s),
                     apply=apply,
                     mask_state=lambda mask, new, old: new)


# -- legacy functional API (kept for external callers/tests) ----------------


def nesterov_init(params: PyTree, state_dtype=jnp.float32) -> PyTree:
    return nesterov(0.0, 0.0, state_dtype=state_dtype).init(params)


def nesterov_step(outer_params: PyTree, pseudograd: PyTree, state: PyTree, *,
                  lr: float, momentum: float) -> tuple[PyTree, PyTree]:
    return nesterov(lr, momentum).apply(outer_params, pseudograd, state)
