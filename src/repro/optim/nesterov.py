"""Outer optimizer: SGD with Nesterov momentum over pseudogradients.

Exactly the paper's Eq. (3) / Algorithm 1 lines 12-13:

    u^(t)     = mu * u^(t-H) + eta_out * Psi^(t)
    theta^(t) = theta^(t-1) - mu * u^(t) - eta_out * Psi^(t)

where Psi is the averaged weight-space delta (pseudogradient). Note the
paper folds eta_out into the momentum accumulator (SlowMo-style), so the
effective step is mu*u + eta_out*Psi.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def nesterov_init(params: PyTree, state_dtype=jnp.float32) -> PyTree:
    return {"u": jax.tree.map(lambda p: jnp.zeros(p.shape, state_dtype), params)}


def nesterov_step(
    outer_params: PyTree,
    pseudograd: PyTree,
    state: PyTree,
    *,
    lr: float,
    momentum: float,
) -> tuple[PyTree, PyTree]:
    def upd(p, psi, u):
        psi = psi.astype(jnp.float32)
        u_new = momentum * u.astype(jnp.float32) + lr * psi
        p_new = p.astype(jnp.float32) - momentum * u_new - lr * psi
        return p_new.astype(p.dtype), u_new.astype(u.dtype)

    out = jax.tree.map(upd, outer_params, pseudograd, state["u"])
    is_tup = lambda t: isinstance(t, tuple)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is_tup)
    new_u = jax.tree.map(lambda t: t[1], out, is_leaf=is_tup)
    return new_params, {"u": new_u}
