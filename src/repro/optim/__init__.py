"""Composable optimizer stack: one ``Transform`` protocol from the inner
step to the outer sync.

Layers:

* :mod:`repro.optim.transform` — the ``Transform`` protocol (``init`` /
  ``update`` / terminal ``apply``) with ``chain`` and ``partition``
  combinators;
* :func:`repro.optim.base.descend` — wraps a direction-producing chain into
  an ``Optimizer`` (schedule + per-leaf lr scaling + decoupled weight decay,
  bit-identical to the legacy arithmetic);
* inner optimizers (:func:`make_inner_optimizer` registry, the names the
  ``--inner`` CLI flag accepts):

  - ``adamw``   — DiLoCo's inner optimizer (:mod:`repro.optim.adamw`);
  - ``muon``    — MuLoCo: momentum + Newton–Schulz on hidden matrices,
    AdamW fallback elsewhere via ``partition`` (:mod:`repro.optim.muon`);
  - ``muon_bp`` — block-periodic Muon; NS every ``OptimizerConfig.ns_period``
    steps, momentum-SGD between (:mod:`repro.optim.muon_variants`);
  - ``normuon`` — Muon + neuron-wise RMS post-scaling
    (:mod:`repro.optim.muon_variants`);

* outer transforms (``--outer``): ``nesterov`` (paper Eq. 3, optional fused
  Pallas kernel routing) and ``sgd`` (:mod:`repro.optim.nesterov`).
"""
from repro.optim.adamw import adamw, scale_by_adam  # noqa: F401
from repro.optim.base import (  # noqa: F401
    Optimizer,
    OptimizerConfig,
    constant_schedule,
    cosine_schedule,
    descend,
    make_schedule,
)
from repro.optim.muon import (  # noqa: F401
    muon,
    muon_label,
    newton_schulz,
    orthogonalize,
    param_labels,
    trace_momentum,
)
from repro.optim.muon_variants import (  # noqa: F401
    muon_bp,
    normuon,
    orthogonalize_periodic,
    scale_by_neuron_rms,
)
from repro.optim.nesterov import (  # noqa: F401
    nesterov,
    nesterov_init,
    nesterov_step,
    outer_sgd,
)
from repro.optim.transform import (  # noqa: F401
    Transform,
    apply_updates,
    chain,
    identity,
    partition,
    scale_by_schedule,
    stateless,
)

# Single-source registries: the CLI choice lists and the builder dispatch
# derive from the same dicts, so adding a variant is one entry.
_INNER_BUILDERS = {"adamw": adamw, "muon": muon, "muon_bp": muon_bp,
                   "normuon": normuon}
_OUTER_BUILDERS = {
    "nesterov": lambda lr, momentum, state_dtype, kernel: nesterov(
        lr, momentum, state_dtype=state_dtype, kernel=kernel),
    "sgd": lambda lr, momentum, state_dtype, kernel: outer_sgd(lr),
}
INNER_OPTIMIZERS = tuple(_INNER_BUILDERS)
OUTER_OPTIMIZERS = tuple(_OUTER_BUILDERS)


def make_inner_optimizer(name: str, cfg: OptimizerConfig, **kw) -> Optimizer:
    """Registry used by DiLoCo: 'adamw' -> DiLoCo, 'muon' -> MuLoCo, plus the
    chain-built variants 'muon_bp' (block-periodic NS) and 'normuon'."""
    if name not in _INNER_BUILDERS:
        raise ValueError(f"unknown inner optimizer {name!r} "
                         f"(have {sorted(_INNER_BUILDERS)})")
    if name == "adamw":
        kw.pop("ns_impl", None)
    return _INNER_BUILDERS[name](cfg, **kw)


def make_outer_transform(name: str, lr: float, momentum: float, *,
                         state_dtype="float32", kernel: bool = False) -> Transform:
    """Registry for the outer (pseudogradient) descent: 'nesterov' | 'sgd'."""
    import jax.numpy as jnp

    if name not in _OUTER_BUILDERS:
        raise ValueError(f"unknown outer optimizer {name!r} "
                         f"(have {sorted(_OUTER_BUILDERS)})")
    return _OUTER_BUILDERS[name](lr, momentum, jnp.dtype(state_dtype), kernel)
