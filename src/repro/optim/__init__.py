from repro.optim.adamw import adamw  # noqa: F401
from repro.optim.base import (  # noqa: F401
    Optimizer,
    OptimizerConfig,
    constant_schedule,
    cosine_schedule,
    make_schedule,
)
from repro.optim.muon import muon, muon_label, newton_schulz, param_labels  # noqa: F401
from repro.optim.nesterov import nesterov_init, nesterov_step  # noqa: F401


def make_inner_optimizer(name: str, cfg: OptimizerConfig, **kw) -> Optimizer:
    """Registry used by DiLoCo: 'adamw' -> DiLoCo, 'muon' -> MuLoCo."""
    if name == "adamw":
        return adamw(cfg)
    if name == "muon":
        return muon(cfg, **kw)
    raise ValueError(f"unknown inner optimizer {name!r}")
