"""Muon variants as small deltas on the transform chain — the payoff of the
composable stack: each is a ~40-line module, not a fork of muon.py.

* ``muon_bp`` — block-periodic Muon (MuonBP, Khaled et al., 2025):
  orthogonalize every ``cfg.ns_period`` steps, plain momentum-SGD between.
  In DiLoCo the round boundary naturally aligns with the period (workers
  reset every H steps), so ``ns_period=H`` orthogonalizes exactly once per
  round. At period 1 this IS Muon (the periodic stage is bypassed).

* ``normuon`` — neuron-wise second-moment normalization (NorMuon, Li et al.,
  2025): after Newton–Schulz, each output neuron (row of the [..., m, n]
  update) is rescaled by its running RMS, then the per-matrix norm is
  restored so Muon's shape-scaled lr transfer still applies.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import shard_hint
from repro.optim.base import Optimizer, OptimizerConfig, descend
from repro.optim.muon import (
    muon_mults,
    muon_partition,
    ns_fn_for,
    orthogonalize,
    trace_momentum,
)
from repro.optim.transform import Transform, chain
from repro.utils.tree import tree_unzip

PyTree = Any


def orthogonalize_periodic(cfg: OptimizerConfig, ns_impl: str = "jnp") -> Transform:
    """NS every ``cfg.ns_period`` steps; raw momentum (momentum-SGD) between.

    The branch is a ``lax.cond`` on an own step counter, so the round
    executor stays a single traced program. (On CPU, vmap over workers
    lowers cond to select — both branches execute — so the FLOP saving only
    materializes on accelerators / unbatched paths; the API and update rule
    are what this module pins down.)
    """
    if cfg.ns_period <= 1:
        return orthogonalize(cfg, ns_impl)
    ns_fn = ns_fn_for(ns_impl)
    iters, period = cfg.ns_iters, cfg.ns_period

    def init(tree: PyTree) -> PyTree:
        return {"count": jnp.zeros((), jnp.int32)}

    def update(updates: PyTree, state: PyTree, params: PyTree):
        count = state["count"] + 1
        do_ns = (count - 1) % period == 0  # orthogonalize on step 1, 1+b, ...

        def orth(x):
            # same layer-parallel resharding hints as `orthogonalize`: whole
            # matrices on one chip around NS, zero-collective iterations
            x = shard_hint(x, "ns_matrix")
            return shard_hint(ns_fn(x, iters=iters).astype(jnp.float32), "ns_out")

        def per_leaf(m):
            return jax.lax.cond(do_ns, orth, lambda x: x.astype(jnp.float32), m)

        return jax.tree.map(per_leaf, updates), {"count": count}

    return Transform(init=init, update=update)


def muon_bp(cfg: OptimizerConfig, ns_impl: str = "jnp",
            adamw_lr_ratio: float = 1.0) -> Optimizer:
    """Block-periodic Muon: ``cfg.ns_period`` controls the NS cadence."""
    tx = muon_partition(cfg, chain(trace_momentum(cfg),
                                   orthogonalize_periodic(cfg, ns_impl)))
    return descend(tx, cfg, muon_mults(cfg, adamw_lr_ratio))


def scale_by_neuron_rms(cfg: OptimizerConfig) -> Transform:
    """NorMuon post-scaling: divide each output neuron (row) by its running
    second-moment RMS, then restore the per-matrix Frobenius norm.

    State is one ``[..., m, 1]`` buffer per hidden matrix, stored in
    ``cfg.state_dtype`` (the 2nd-moment cost is m, not m*n)."""
    b2, eps = cfg.b2, cfg.eps
    sdt = jnp.dtype(cfg.state_dtype)

    def init(tree: PyTree) -> PyTree:
        return {
            "v": jax.tree.map(lambda p: jnp.zeros((*p.shape[:-1], 1), sdt), tree),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(updates: PyTree, state: PyTree, params: PyTree):
        count = state["count"] + 1
        bc2 = 1.0 - b2 ** count.astype(jnp.float32)

        def upd(u, v):
            u = u.astype(jnp.float32)
            v = b2 * v.astype(jnp.float32) + (1.0 - b2) * jnp.mean(
                u * u, axis=-1, keepdims=True)
            vhat = v / bc2
            un = u / (jnp.sqrt(vhat) + eps)
            # restore the per-matrix norm so the orthogonalized scale survives
            axes = (-2, -1)
            norm_u = jnp.sqrt(jnp.sum(u * u, axis=axes, keepdims=True))
            norm_un = jnp.sqrt(jnp.sum(un * un, axis=axes, keepdims=True))
            return un * (norm_u / (norm_un + eps)), v.astype(sdt)

        u, new_v = tree_unzip(jax.tree.map(upd, updates, state["v"]), 2)
        return u, {"v": new_v, "count": count}

    return Transform(init=init, update=update)


def normuon(cfg: OptimizerConfig, ns_impl: str = "jnp",
            adamw_lr_ratio: float = 1.0) -> Optimizer:
    """NorMuon: Muon + neuron-wise RMS post-scaling after Newton–Schulz."""
    tx = muon_partition(cfg, chain(trace_momentum(cfg),
                                   orthogonalize(cfg, ns_impl),
                                   scale_by_neuron_rms(cfg)))
    return descend(tx, cfg, muon_mults(cfg, adamw_lr_ratio))
