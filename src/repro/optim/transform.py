"""The ``Transform`` protocol: one composable update-algebra from the inner
optimizer step to the outer pseudogradient sync.

A ``Transform`` is an optax-style pair of pure functions plus two optional
hooks used by *terminal* (parameter-applying) stages::

    state            = t.init(tree)
    updates, state   = t.update(updates, state, params)
    params, state    = t.apply(params, updates, state)        # terminal only
    state            = t.mask_state(mask, new_state, old)     # streaming sync

``update`` rewrites an update pytree (gradients, momenta, worker deltas,
pseudogradients — anything flowing toward the parameters) while threading its
own state. ``chain`` composes transforms left to right; ``partition`` routes
disjoint parameter groups through different transforms (Muon's hidden-matrix
vs embeddings/norms split is ``partition(muon_label, ...)``).

Why terminal stages get an ``apply`` hook instead of folding everything into
additive updates: the repo's regression guard requires *bit-exact* parity
with the pre-transform optimizers, whose decoupled weight decay evaluates
``(p - lr*u) - lr*wd*p``. Floating-point addition is not associative, so a
``p + combined_update`` application cannot reproduce it; the terminal stage
therefore sees the params and performs the descent itself (this is also what
lets the outer Nesterov route through the fused Pallas kernel, which produces
``(theta', u')`` in one pass). Non-terminal chains still compose purely on
updates.

Partitioned trees use ``None`` holes: ``partition`` replaces out-of-group
leaves with ``None`` (an empty pytree node), so sub-transform states are only
materialized for the leaves they own — Muon's 3x-vs-4x memory advantage over
AdamW (paper Tab. 9) falls out of the AdamW second moment simply not
existing for hidden matrices.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax

from repro.utils.tree import tree_map_with_path

PyTree = Any


class Transform(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]
    # terminal stages only: (params, updates, state) -> (new_params, new_state)
    apply: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]] | None = None
    # streaming (masked) sync: (mask, new_state, old_state) -> merged_state
    mask_state: Callable[[PyTree, PyTree, PyTree], PyTree] | None = None


def identity() -> Transform:
    """The unit of ``chain``: passes updates through, holds no state."""
    return Transform(init=lambda tree: (),
                     update=lambda u, s, p: (u, s))


def stateless(fn: Callable[[PyTree, PyTree], PyTree]) -> Transform:
    """Lift ``fn(updates, params) -> updates`` into a stateless Transform."""
    return Transform(init=lambda tree: (),
                     update=lambda u, s, p: (fn(u, p), s))


def chain(*transforms: Transform) -> Transform:
    """Compose transforms left to right; state is the tuple of stage states.

    Only the last stage may be terminal (define ``apply``); ``chain``
    delegates ``apply``/``mask_state`` to it. Associative on the updates it
    produces: ``chain(a, chain(b, c))`` and ``chain(chain(a, b), c)`` rewrite
    updates identically (their states nest differently).
    """
    for t in transforms[:-1]:
        if t.apply is not None:
            raise ValueError("only the final transform in a chain may be "
                             "terminal (define apply)")

    def init(tree: PyTree) -> PyTree:
        return tuple(t.init(tree) for t in transforms)

    def update(updates: PyTree, state: PyTree, params: PyTree):
        new_states = []
        for t, s in zip(transforms, state):
            updates, s = t.update(updates, s, params)
            new_states.append(s)
        return updates, tuple(new_states)

    apply = None
    mask_state = None
    if transforms and transforms[-1].apply is not None:
        last = transforms[-1]

        def apply(params: PyTree, updates: PyTree, state: PyTree):
            new_params, last_state = last.apply(params, updates, state[-1])
            return new_params, (*state[:-1], last_state)

        if last.mask_state is not None:
            def mask_state(mask, new_state, old_state):
                merged = last.mask_state(mask, new_state[-1], old_state[-1])
                return (*new_state[:-1], merged)

    return Transform(init=init, update=update, apply=apply,
                     mask_state=mask_state)


# ---------------------------------------------------------------------------
# partition: route disjoint parameter groups through different transforms
# ---------------------------------------------------------------------------


def _group(labels: PyTree, tree: PyTree, name: str) -> PyTree:
    """Copy of ``tree`` with out-of-group leaves replaced by ``None`` holes."""
    return jax.tree.map(lambda lb, x: x if lb == name else None, labels, tree)


def _merge(labels: PyTree, group_trees: dict[str, PyTree]) -> PyTree:
    """Inverse of ``_group``: reassemble one full tree from the group trees.

    ``None`` removal preserves leaf order, so each group's leaves stream back
    into the full structure in flattening order.
    """
    labels_flat, treedef = jax.tree.flatten(labels)
    its = {name: iter(jax.tree.leaves(t)) for name, t in group_trees.items()}
    return jax.tree.unflatten(treedef, [next(its[lb]) for lb in labels_flat])


def partition(label_fn: Callable[[str, Any], str],
              transforms: dict[str, Transform]) -> Transform:
    """Apply a different transform per parameter group.

    ``label_fn(path, leaf) -> group name`` assigns every leaf to exactly one
    group (e.g. :func:`repro.optim.muon.muon_label`). Each group's transform
    sees the tree with all other groups' leaves masked to ``None``, so its
    state only holds buffers for the leaves it owns.
    """

    def labels_of(tree: PyTree) -> PyTree:
        labels = tree_map_with_path(label_fn, tree)
        seen = set(jax.tree.leaves(labels))
        unknown = seen - set(transforms)
        if unknown:
            raise ValueError(f"label_fn produced groups {sorted(unknown)} "
                             f"with no transform (have {sorted(transforms)})")
        return labels

    def init(tree: PyTree) -> PyTree:
        labels = labels_of(tree)
        return {name: t.init(_group(labels, tree, name))
                for name, t in transforms.items()}

    def update(updates: PyTree, state: PyTree, params: PyTree):
        labels = labels_of(params)
        outs, new_states = {}, {}
        for name, t in transforms.items():
            outs[name], new_states[name] = t.update(
                _group(labels, updates, name), state[name],
                _group(labels, params, name))
        return _merge(labels, outs), new_states

    return Transform(init=init, update=update)


# ---------------------------------------------------------------------------
# Generic building-block transforms
# ---------------------------------------------------------------------------


def scale_by_schedule(sched: Callable) -> Transform:
    """Multiply updates by ``sched(count)`` with an own step counter."""
    import jax.numpy as jnp

    def init(tree: PyTree) -> PyTree:
        return {"count": jnp.zeros((), jnp.int32)}

    def update(updates: PyTree, state: PyTree, params: PyTree):
        count = state["count"] + 1
        s = sched(count)
        return jax.tree.map(lambda x: s * x, updates), {"count": count}

    return Transform(init=init, update=update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    """Default application for non-terminal chains: p <- p + u (fp32 math)."""
    import jax.numpy as jnp

    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params, updates)
