"""AdamW — the paper's DiLoCo inner optimizer and DP baseline.

Fused update semantics match torch.optim.AdamW (decoupled weight decay,
bias-corrected moments). Paper setting: b1=0.9, b2=0.99.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer, OptimizerConfig, make_schedule

PyTree = Any


def adamw(cfg: OptimizerConfig) -> Optimizer:
    sched = make_schedule(cfg)

    def init(params: PyTree) -> PyTree:
        sdt = jnp.dtype(cfg.state_dtype)
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def step(params: PyTree, grads: PyTree, state: PyTree):
        count = state["count"] + 1
        lr = sched(count)
        b1, b2, eps, wd = cfg.b1, cfg.b2, cfg.eps, cfg.weight_decay
        bc1 = 1.0 - b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - b2 ** count.astype(jnp.float32)

        sdt = jnp.dtype(cfg.state_dtype)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m.astype(jnp.float32) + (1.0 - b1) * g
            v = b2 * v.astype(jnp.float32) + (1.0 - b2) * g * g
            mhat = m / bc1
            vhat = v / bc2
            u = mhat / (jnp.sqrt(vhat) + eps)
            p32 = p.astype(jnp.float32)
            new_p = p32 - lr * u - lr * wd * p32
            return new_p.astype(p.dtype), m.astype(sdt), v.astype(sdt)

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        # out is a tree of 3-tuples; transpose it back into three trees
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "v": new_v, "count": count}

    return Optimizer(init=init, step=step)
