"""AdamW — the paper's DiLoCo inner optimizer and DP baseline.

Expressed as a transform chain: :func:`scale_by_adam` produces the
bias-corrected Adam direction, and :func:`repro.optim.base.descend` applies
it with the schedule and decoupled weight decay. Update semantics match
torch.optim.AdamW. Paper setting: b1=0.9, b2=0.99.

``scale_by_adam`` is also the AdamW fallback group inside Muon's
``partition`` (embeddings/norms/head), where its second-moment buffers only
exist for the leaves it owns.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer, OptimizerConfig, descend
from repro.optim.transform import Transform
from repro.utils.tree import tree_unzip

PyTree = Any


def scale_by_adam(cfg: OptimizerConfig) -> Transform:
    """u = (m / bc1) / (sqrt(v / bc2) + eps), moments stored in state_dtype."""
    b1, b2, eps = cfg.b1, cfg.b2, cfg.eps
    sdt = jnp.dtype(cfg.state_dtype)

    def init(tree: PyTree) -> PyTree:
        def zeros(p):
            return jnp.zeros(p.shape, sdt)

        return {
            "m": jax.tree.map(zeros, tree),
            "v": jax.tree.map(zeros, tree),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(updates: PyTree, state: PyTree, params: PyTree):
        count = state["count"] + 1
        bc1 = 1.0 - b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - b2 ** count.astype(jnp.float32)

        def upd(g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m.astype(jnp.float32) + (1.0 - b1) * g
            v = b2 * v.astype(jnp.float32) + (1.0 - b2) * g * g
            mhat = m / bc1
            vhat = v / bc2
            u = mhat / (jnp.sqrt(vhat) + eps)
            return u, m.astype(sdt), v.astype(sdt)

        u, new_m, new_v = tree_unzip(jax.tree.map(upd, updates, state["m"], state["v"]), 3)
        return u, {"m": new_m, "v": new_v, "count": count}

    return Transform(init=init, update=update)


def adamw(cfg: OptimizerConfig) -> Optimizer:
    return descend(scale_by_adam(cfg), cfg)
