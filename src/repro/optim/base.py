"""Minimal functional optimizer interface (no optax dependency).

An ``Optimizer`` is a pair of pure functions:

    state  = opt.init(params)
    params, state = opt.step(params, grads, state)

``state`` always contains an integer ``count`` leaf so learning-rate
schedules are resolved inside ``step`` (keeps the DiLoCo inner loop a single
jittable function). All optimizer math is done in fp32 regardless of the
parameter dtype, and results are cast back.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jax.Array], jax.Array]  # step -> lr multiplier (absolute lr)


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    step: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    """Shared hyperparameters for inner optimizers."""

    lr: float = 1e-3
    weight_decay: float = 0.0
    b1: float = 0.9
    b2: float = 0.99
    eps: float = 1e-8
    # Muon-specific
    ns_iters: int = 5
    muon_lr_scale_mode: str = "paper"  # paper: sqrt(n/m) | jordan: sqrt(max(1,m/n)) | none
    # schedule
    schedule: str = "constant"  # constant | cosine
    warmup_steps: int = 0
    total_steps: int = 1
    min_lr_ratio: float = 0.1
    # dtype of persistent optimizer state (momenta); math is always fp32
    state_dtype: str = "float32"


def constant_schedule(lr: float) -> Schedule:
    def sched(step):
        return jnp.asarray(lr, jnp.float32)

    return sched


def cosine_schedule(lr: float, total_steps: int, warmup_steps: int = 0, min_ratio: float = 0.1) -> Schedule:
    """Linear warmup followed by cosine decay to ``min_ratio * lr`` (paper: 0.1x)."""

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.asarray(max(warmup_steps, 1), jnp.float32)
        total = jnp.asarray(max(total_steps, 1), jnp.float32)
        warm_lr = lr * jnp.minimum(step / warm, 1.0)
        frac = jnp.clip((step - warm) / jnp.maximum(total - warm, 1.0), 0.0, 1.0)
        cos = min_ratio + (1.0 - min_ratio) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        decay_lr = lr * cos
        return jnp.where(step < warmup_steps, warm_lr, decay_lr).astype(jnp.float32)

    return sched


def make_schedule(cfg: OptimizerConfig) -> Schedule:
    if cfg.schedule == "constant":
        return constant_schedule(cfg.lr)
    if cfg.schedule == "cosine":
        return cosine_schedule(cfg.lr, cfg.total_steps, cfg.warmup_steps, cfg.min_lr_ratio)
    raise ValueError(f"unknown schedule {cfg.schedule!r}")


def apply_update(param: jax.Array, update: jax.Array, lr, weight_decay) -> jax.Array:
    """Decoupled weight decay update: p <- p - lr*update - lr*wd*p (fp32 math)."""
    p32 = param.astype(jnp.float32)
    new = p32 - lr * update.astype(jnp.float32) - lr * weight_decay * p32
    return new.astype(param.dtype)
