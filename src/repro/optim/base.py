"""Minimal functional optimizer interface (no optax dependency).

An ``Optimizer`` is a pair of pure functions:

    state  = opt.init(params)
    params, state = opt.step(params, grads, state)

``state`` always contains an integer ``count`` leaf so learning-rate
schedules are resolved inside ``step`` (keeps the DiLoCo inner loop a single
jittable function). All optimizer math is done in fp32 regardless of the
parameter dtype, and results are cast back.

Optimizers are built from :class:`repro.optim.transform.Transform` chains via
:func:`descend`, which turns "gradients -> update direction" transforms into
a full descent step with schedule, per-leaf lr scaling, and decoupled weight
decay — evaluated with exactly the legacy arithmetic ``(p - lr*u) - lr*wd*p``
so refactors of the chain stay bit-for-bit reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import tree_util as jtu

PyTree = Any
Schedule = Callable[[jax.Array], jax.Array]  # step -> lr multiplier (absolute lr)


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    step: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    """Shared hyperparameters for inner optimizers."""

    lr: float = 1e-3
    weight_decay: float = 0.0
    b1: float = 0.9
    b2: float = 0.99
    eps: float = 1e-8
    # Muon-specific
    ns_iters: int = 5
    muon_lr_scale_mode: str = "paper"  # paper: sqrt(n/m) | jordan: sqrt(max(1,m/n)) | none
    # MuonBP (Khaled et al., 2025): orthogonalize every ns_period steps,
    # momentum-SGD between. 1 = plain Muon.
    ns_period: int = 1
    # schedule
    schedule: str = "constant"  # constant | cosine
    warmup_steps: int = 0
    total_steps: int = 1
    min_lr_ratio: float = 0.1
    # dtype of persistent optimizer state (momenta); math is always fp32
    state_dtype: str = "float32"


def constant_schedule(lr: float) -> Schedule:
    def sched(step):
        return jnp.asarray(lr, jnp.float32)

    return sched


def cosine_schedule(lr: float, total_steps: int, warmup_steps: int = 0, min_ratio: float = 0.1) -> Schedule:
    """Linear warmup followed by cosine decay to ``min_ratio * lr`` (paper: 0.1x)."""

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.asarray(max(warmup_steps, 1), jnp.float32)
        total = jnp.asarray(max(total_steps, 1), jnp.float32)
        warm_lr = lr * jnp.minimum(step / warm, 1.0)
        frac = jnp.clip((step - warm) / jnp.maximum(total - warm, 1.0), 0.0, 1.0)
        cos = min_ratio + (1.0 - min_ratio) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        decay_lr = lr * cos
        return jnp.where(step < warmup_steps, warm_lr, decay_lr).astype(jnp.float32)

    return sched


def make_schedule(cfg: OptimizerConfig) -> Schedule:
    if cfg.schedule == "constant":
        return constant_schedule(cfg.lr)
    if cfg.schedule == "cosine":
        return cosine_schedule(cfg.lr, cfg.total_steps, cfg.warmup_steps, cfg.min_lr_ratio)
    raise ValueError(f"unknown schedule {cfg.schedule!r}")


def apply_update(param: jax.Array, update: jax.Array, lr, weight_decay) -> jax.Array:
    """Decoupled weight decay update: p <- p - lr*update - lr*wd*p (fp32 math)."""
    p32 = param.astype(jnp.float32)
    new = p32 - lr * update.astype(jnp.float32) - lr * weight_decay * p32
    return new.astype(param.dtype)


# ---------------------------------------------------------------------------
# Transform chain -> Optimizer
# ---------------------------------------------------------------------------

# mults_fn(path, leaf) -> (update_lr_scale, decay_lr_scale): python floats
# multiplying the scheduled lr for the descent term and the decay term of one
# leaf. Muon's sqrt(n/m) shape scaling and its AdamW-fallback lr ratio are
# both expressed through this hook.
MultsFn = Callable[[str, Any], tuple[float, float]]


def descend(tx: "Any", cfg: OptimizerConfig, mults_fn: MultsFn | None = None,
            sched: Schedule | None = None) -> Optimizer:
    """Wrap a direction-producing Transform chain into a full Optimizer.

    The chain maps gradients to an update direction ``u``; ``descend`` then
    performs the decoupled-weight-decay descent

        p <- p - (lr * u_scale) * u - ((lr * d_scale) * wd) * p

    with exactly that association/order of operations (bit-identical to the
    pre-transform optimizers, which the fixed-seed parity guard pins down).
    State is ``{"tx": chain_state, "count": i32}``; lr is resolved from the
    schedule on the incremented count each step.
    """
    from repro.utils.tree import path_str

    sched = sched or make_schedule(cfg)
    wd = cfg.weight_decay

    def init(params: PyTree) -> PyTree:
        return {"tx": tx.init(params), "count": jnp.zeros((), jnp.int32)}

    def step(params: PyTree, grads: PyTree, state: PyTree):
        count = state["count"] + 1
        lr = sched(count)
        u, tx_state = tx.update(grads, state["tx"], params)

        def apply(path, p, u_leaf):
            u_scale, d_scale = mults_fn(path_str(path), p) if mults_fn else (1.0, 1.0)
            um = lr * u_scale
            dm = (lr * d_scale) * wd
            p32 = p.astype(jnp.float32)
            return (p32 - um * u_leaf - dm * p32).astype(p.dtype)

        new_params = jtu.tree_map_with_path(apply, params, u)
        return new_params, {"tx": tx_state, "count": count}

    return Optimizer(init=init, step=step)
