from repro.roofline.analysis import (  # noqa: F401
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
    RooflineTerms,
    active_params,
    model_flops,
    parse_collective_bytes,
)
