"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs / (chips * peak_FLOPs)       [cost_analysis]
    memory     = HLO_bytes / (chips * HBM_bw)           [cost_analysis]
    collective = sum(collective op bytes) / (chips * link_bw)   [HLO text]

cost_analysis() on an SPMD-partitioned executable reports *per-device*
flops/bytes, so terms divide by per-chip peaks directly. Collective bytes are
parsed from the optimized HLO: the result-shape bytes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute (per device).
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e per-chip constants (brief)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  "f32[8,128]{1,0}"  or  "(bf16[2,4]{1,0}, f32[8]{0})"
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op in optimized HLO."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        lhs, rhs = line.split("=", 1)
        rhs = rhs.strip()
        # result type is the prefix of rhs before the op name
        for coll in _COLLECTIVES:
            # match op name at word boundary followed by '(' or '-start('
            m = re.search(rf"\b{coll}(-start|-done)?\(", rhs)
            if m:
                if m.group(1) == "-done":
                    break  # counted at -start
                type_prefix = rhs[: m.start()]
                out[coll] += _shape_bytes(type_prefix)
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    hlo_bytes: float
    collective_bytes: float
    chips: int
    model_flops: float = 0.0
    amortize: float = 1.0  # divide by H for the sync step
    # measured cross-worker pseudogradient wire bytes for the whole program
    # (per worker, from the actual wire buffers — collectives.
    # measured_sync_bytes), as opposed to the HLO-parsed on-mesh collective
    # bytes above. 0 for programs without an outer sync.
    wire_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS / self.amortize

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW / self.amortize

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / LINK_BW / self.amortize

    @property
    def wire_comm_s(self) -> float:
        """Cross-worker wire time at ICI link speed (lower bound; the
        cross-DC links DiLoCo targets are slower — scale by LINK_BW/bw)."""
        return self.wire_bytes / LINK_BW / self.amortize

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per-chip HLO flops x chips)."""
        total_hlo = self.flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hlo_bytes_per_chip": self.hlo_bytes,
            "collective_bytes_per_chip": self.collective_bytes,
            "wire_bytes_per_worker": self.wire_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "wire_comm_s": self.wire_comm_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def model_flops(kind: str, n_active_params: float, tokens: float) -> float:
    """6*N*D for train, 2*N*D for inference forward (per step, all chips).

    ``round`` (the engine's fused H-step+sync executor) passes the round's
    total token count, so it is 6*N*D like train."""
    if kind in ("train", "round"):
        return 6.0 * n_active_params * tokens
    if kind in ("prefill", "decode"):
        return 2.0 * n_active_params * tokens
    return 0.0


def active_params(cfg, total_params: float) -> float:
    """MoE active params: replace routed-expert mass with top-k fraction."""
    if not cfg.n_experts:
        return total_params
    # routed expert params per layer: 3 * d_model * d_ff per expert
    routed = cfg.n_layers * cfg.n_experts * 3 * cfg.d_model * cfg.d_ff
    active_routed = routed * (cfg.experts_per_token / cfg.n_experts)
    return total_params - routed + active_routed
