"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
JSON records.

    PYTHONPATH=src python -m repro.roofline.report --dryrun results/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json


def load_records(dryrun_dir: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(f"{dryrun_dir}/*.json")):
        recs.extend(json.load(open(path)))
    return recs


def _fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def roofline_table(recs: list[dict], mesh: str = "16x16") -> str:
    """Single-pod baseline roofline table, one row per (arch, shape, plan)."""
    lines = [
        "| arch | shape | plan | compute s | memory s | collective s | dominant | useful | peak GiB/chip |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r.get("plan", ""))):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | SKIP | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r.get('plan')} | FAIL | | | | | |")
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['plan']} | {t['compute_s']:.2e} | "
            f"{t['memory_s']:.2e} | {t['collective_s']:.2e} | **{t['dominant']}** | "
            f"{t['useful_flops_ratio']:.2f} | {r['memory']['peak_per_chip_gib']} |"
        )
    return "\n".join(lines)


def dryrun_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | plan | mesh | compile s | args GiB | temp GiB | collective GiB (loop-corrected / flat) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"], r.get("plan", ""))):
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | {r['mesh']} | SKIP | | | {r['reason']} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r.get('plan')} | {r['mesh']} | FAIL | | | {r['error'][:80]} |")
            continue
        m = r["memory"]
        c = r["collectives"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['plan']} | {r['mesh']} | {r['compile_s']} | "
            f"{_fmt_bytes(m['argument_bytes'])} | {_fmt_bytes(m['temp_bytes'])} | "
            f"{_fmt_bytes(c['total'])} / {_fmt_bytes(c.get('flat_total', 0))} |"
        )
    return "\n".join(lines)


def summarize_bottlenecks(recs: list[dict]) -> str:
    ok = [r for r in recs if r["status"] == "ok" and r["mesh"] == "16x16"]
    by_dom: dict[str, int] = {}
    worst = []
    for r in ok:
        t = r["roofline"]
        by_dom[t["dominant"]] = by_dom.get(t["dominant"], 0) + 1
        dom_s = max(t["compute_s"], t["memory_s"], t["collective_s"])
        frac = t["compute_s"] / dom_s if dom_s else 0.0
        worst.append((frac, f"{r['arch']}/{r['shape']}/{r['plan']}", t["dominant"]))
    worst.sort()
    lines = [f"Dominant-term census (single pod): {by_dom}", "",
             "Worst roofline fraction (compute_s / dominant_s — lower = further from compute-bound):"]
    for frac, name, dom in worst[:8]:
        lines.append(f"  {frac:8.4f}  {name}  (bound by {dom})")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--what", default="all", choices=["all", "roofline", "dryrun", "summary"])
    args = ap.parse_args()
    recs = load_records(args.dryrun)
    if args.what in ("all", "summary"):
        print(summarize_bottlenecks(recs))
        print()
    if args.what in ("all", "roofline"):
        print("### Roofline (single-pod 16x16 baseline)\n")
        print(roofline_table(recs))
        print()
    if args.what in ("all", "dryrun"):
        print("### Dry-run records (both meshes)\n")
        print(dryrun_table(recs))


if __name__ == "__main__":
    main()
