"""Analytic FLOP and HBM-traffic models, exact to the model code.

XLA's ``cost_analysis()`` does not multiply while-loop (lax.scan) bodies by
their trip count, so HLO FLOPs under-count scan-over-layers models by ~L.
(Verified empirically; see tests/test_roofline.py which validates these
formulas against *unrolled* HLO to within a few percent.) The roofline
compute/memory terms therefore come from these closed-form models; the raw
HLO numbers are recorded alongside for reference, and collective bytes are
parsed from HLO with explicit loop-multiplicity correction.

Conventions: a matmul [m,k]x[k,n] costs 2mkn; backward = 2x forward matmul
cost; remat adds one extra forward through scanned blocks.
"""
from __future__ import annotations

import dataclasses

from repro.models.common import ModelConfig


def _attn_flops(cfg: ModelConfig, S: int, T: int, kv_len: int | None = None) -> float:
    """Forward attention flops for T query tokens (seq len S context).

    kv_len overrides context length (decode: cache length; sliding window).
    Full-seq training/prefill at blockwise lengths uses the attention
    impls' *visit schedule* (block-granular causal/sliding-window skipping,
    shared by the Pallas flash kernel and the XLA blockwise fallback) as
    the effective-context term, instead of the smooth ctx/2 approximation —
    the roofline then counts exactly the score/PV blocks the kernels run.
    """
    hd = cfg.hd
    H, KV = cfg.n_heads, cfg.n_kv_heads
    d = cfg.d_model
    ctx = kv_len if kv_len is not None else S
    if cfg.sliding_window:
        ctx = min(ctx, cfg.sliding_window)
    proj = 2.0 * T * d * (H * hd) + 2.0 * 2.0 * T * d * (KV * hd) + 2.0 * T * (H * hd) * d
    full_seq = T == S and kv_len is None
    # the Pallas kernel runs the block schedule at every length; the XLA
    # path only above the blockwise threshold
    blocked = cfg.attn_impl == "pallas" or S >= cfg.blockwise_threshold
    if full_seq and blocked:
        from repro.kernels.flash_attention import visited_fraction

        # block-granular skipping: both impls visit exactly this fraction
        eff_ctx = S * visited_fraction(S, cfg.attn_block_q, cfg.attn_block_kv,
                                       causal=True, window=cfg.sliding_window)
    elif full_seq and not cfg.sliding_window:
        eff_ctx = ctx / 2.0  # causal averaging for the dense path
    else:
        eff_ctx = ctx
    scores = 2.0 * T * H * hd * eff_ctx * 2.0
    return proj + scores


def _mlp_flops(cfg: ModelConfig, T: int, d_ff: int | None = None) -> float:
    ff = cfg.d_ff if d_ff is None else d_ff
    mats = 3.0 if cfg.activation == "swiglu" else 2.0
    return mats * 2.0 * T * cfg.d_model * ff


def _moe_flops(cfg: ModelConfig, T: int) -> float:
    router = 2.0 * T * cfg.d_model * cfg.n_experts
    routed = cfg.experts_per_token * 3.0 * 2.0 * T * cfg.d_model * cfg.d_ff
    shared = 0.0
    if cfg.n_shared_experts:
        shared = 3.0 * 2.0 * T * cfg.d_model * (cfg.d_ff * cfg.n_shared_experts)
    return router + routed + shared


def _ssm_flops(cfg: ModelConfig, T: int, decode: bool = False) -> float:
    d, di, N, H, P, Q = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                         cfg.ssm_head_dim, cfg.ssm_chunk)
    proj = 2.0 * T * d * (2 * di + 2 * N + H) + 2.0 * T * di * d
    if decode:
        core = T * H * P * N * 6.0  # state update + readout
    else:
        # chunked SSD: intra-chunk (CB^T QxQ, M*x) + states + off-diag
        intra = 2.0 * T * Q * N + 2.0 * T * Q * H * P
        states = 2.0 * T * N * H * P * 2.0
        core = intra + states
    return proj + core


def _block_flops(cfg: ModelConfig, S: int, T: int, kv_len: int | None = None) -> float:
    """One generic layer for each family (forward)."""
    if cfg.arch_type in ("dense",):
        return _attn_flops(cfg, S, T, kv_len) + _mlp_flops(cfg, T)
    if cfg.arch_type == "moe":
        return _attn_flops(cfg, S, T, kv_len) + _moe_flops(cfg, T)
    if cfg.arch_type == "ssm":
        return _ssm_flops(cfg, T, decode=(T < S))
    raise ValueError(cfg.arch_type)


def forward_flops(cfg: ModelConfig, S: int, B: int, T: int | None = None,
                  kv_len: int | None = None) -> float:
    """Forward flops for B sequences; T = query tokens per sequence
    (T=S for train/prefill, T=1 for decode)."""
    T = S if T is None else T
    tokens = float(B * T)
    head = 2.0 * tokens * cfg.d_model * cfg.vocab if T == S or T == 1 else 0.0
    if T == 1:
        head = 2.0 * B * cfg.d_model * cfg.vocab

    if cfg.arch_type in ("dense", "moe"):
        per_layer = _block_flops(cfg, S, tokens, kv_len)
        return cfg.n_layers * per_layer + head
    if cfg.arch_type == "ssm":
        return cfg.n_layers * _ssm_flops(cfg, tokens, decode=(T == 1)) + head
    if cfg.arch_type == "hybrid":
        n_super = cfg.n_layers // cfg.hybrid_period
        mamba = cfg.n_layers * _ssm_flops(cfg, tokens, decode=(T == 1))
        attn_ctx = kv_len if T == 1 else None
        shared = n_super * (_attn_flops(cfg, S, tokens, attn_ctx) + _mlp_flops(cfg, tokens))
        return mamba + shared + head
    if cfg.arch_type == "audio":
        Le = cfg.n_encoder_layers or cfg.n_layers
        F = cfg.n_audio_frames
        ftoks = float(B * F)
        enc = Le * (_attn_flops(cfg.replace(sliding_window=0), F, ftoks) + _mlp_flops(cfg, ftoks))
        if T == 1:
            enc = 0.0  # encoder runs once per request, not per decode step
        dec_self = cfg.n_layers * _attn_flops(cfg, S, tokens, kv_len)
        cross_kv = 0.0 if T == 1 else cfg.n_layers * 2.0 * 2.0 * ftoks * cfg.d_model * (cfg.n_kv_heads * cfg.hd)
        dec_cross = cfg.n_layers * (2.0 * tokens * cfg.d_model * (cfg.n_heads * cfg.hd)
                                    + 2.0 * tokens * cfg.n_heads * cfg.hd * F * 2.0
                                    + 2.0 * tokens * (cfg.n_heads * cfg.hd) * cfg.d_model)
        dec_mlp = cfg.n_layers * _mlp_flops(cfg, tokens)
        return enc + dec_self + cross_kv + dec_cross + dec_mlp + head
    if cfg.arch_type == "vlm":
        ns = cfg.n_layers // cfg.vlm_period
        n_self = cfg.n_layers - ns
        img = cfg.n_image_tokens
        itoks = float(B * img)
        self_l = n_self * (_attn_flops(cfg, S, tokens, kv_len) + _mlp_flops(cfg, tokens))
        cross_kv = 0.0 if T == 1 else ns * 2.0 * 2.0 * itoks * cfg.d_model * (cfg.n_kv_heads * cfg.hd)
        cross = ns * (2.0 * tokens * cfg.d_model * (cfg.n_heads * cfg.hd)
                      + 2.0 * tokens * cfg.n_heads * cfg.hd * img * 2.0
                      + 2.0 * tokens * (cfg.n_heads * cfg.hd) * cfg.d_model
                      + _mlp_flops(cfg, tokens))
        proj = 2.0 * itoks * cfg.d_model * cfg.d_model if T != 1 else 0.0
        return self_l + cross_kv + cross + proj + head
    raise ValueError(cfg.arch_type)


def newton_schulz_flops(m: int, n: int, iters: int = 5) -> float:
    """Per NS orthogonalization of an [m, n] matrix (m <= n after transpose)."""
    a = min(m, n)
    b = max(m, n)
    per_iter = 2.0 * a * a * b + 2.0 * a * a * a + 2.0 * a * a * b  # XX^T, A@A, B@X
    return iters * per_iter


def optimizer_flops(params_tree, inner_name: str) -> float:
    """Per-step optimizer flops across the whole parameter tree."""
    from repro.optim.muon import muon_label
    from repro.utils.tree import tree_leaves_with_paths

    total = 0.0
    for path, leaf in tree_leaves_with_paths(params_tree):
        size = 1
        for d in leaf.shape:
            size *= int(d)
        # muon_bp/normuon share Muon's NS cost model (muon_bp amortizes it by
        # ns_period on accelerators; we account the orthogonalizing step)
        muon_family = inner_name in ("muon", "muon_bp", "normuon")
        if muon_family and muon_label(path, leaf) == "muon":
            *batch, m, n = leaf.shape
            nb = 1
            for d in batch:
                nb *= int(d)
            total += nb * newton_schulz_flops(int(m), int(n)) + 6.0 * size
        else:
            total += 12.0 * size  # adamw elementwise
    return total


@dataclasses.dataclass
class StepFlops:
    forward: float
    backward: float
    optimizer: float
    remat_extra: float

    @property
    def total(self) -> float:
        return self.forward + self.backward + self.optimizer + self.remat_extra


def train_step_flops(cfg: ModelConfig, S: int, B: int, params_tree, inner_name: str) -> StepFlops:
    fwd = forward_flops(cfg, S, B)
    bwd = 2.0 * fwd
    remat = fwd if cfg.remat else 0.0
    opt = optimizer_flops(params_tree, inner_name)
    return StepFlops(fwd, bwd, opt, remat)


# ---------------------------------------------------------------------------
# HBM traffic (per chip, per step)
# ---------------------------------------------------------------------------


def hbm_bytes(kind: str, *, param_bytes_chip: float, opt_state_bytes_chip: float,
              act_bytes_chip: float, cache_bytes_chip: float = 0.0) -> float:
    """Coarse per-chip HBM traffic model.

    train:   read params (fwd + bwd + remat fwd ~ 3x), read+write opt state,
             write grads + activations ~ 2x act
    prefill: read params once + activation traffic
    decode:  read params + read full cache + small writes  (bandwidth-bound)
    """
    if kind == "train":
        return 3.0 * param_bytes_chip + 2.0 * opt_state_bytes_chip + 2.0 * act_bytes_chip
    if kind == "prefill":
        return param_bytes_chip + 2.0 * act_bytes_chip
    if kind == "decode":
        return param_bytes_chip + cache_bytes_chip + act_bytes_chip
    if kind == "sync":
        # outer step touches outer params + u + worker deltas (+EF)
        return 4.0 * param_bytes_chip + opt_state_bytes_chip
    raise ValueError(kind)
