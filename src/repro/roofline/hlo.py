"""Loop-multiplicity-aware HLO collective accounting.

XLA prints each while-loop body once, but the collectives inside execute
trip-count times per step. This parser:

  1. splits optimized HLO text into named computations,
  2. finds `while` ops and extracts trip counts from their condition
     computations (the `constant(N)` bound of the induction-variable compare),
  3. walks the call graph from ENTRY, multiplying collective bytes by the
     product of enclosing trip counts.

Used for the roofline collective term; the flat (uncorrected) sums are kept
for comparison. Heuristic trip-count extraction (max int constant in the
cond computation) is exact for lax.scan-lowered loops, which is all we emit.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Computation:
    name: str
    lines: list[str] = field(default_factory=list)
    collective_bytes: dict[str, int] = field(default_factory=dict)
    whiles: list[tuple[str, str]] = field(default_factory=list)  # (body, cond)
    calls: list[str] = field(default_factory=list)


def _split_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$", line)
        if m and not line.startswith(" "):
            cur = Computation(name=m.group(2))
            comps[cur.name] = cur
            if m.group(1):
                entry_name = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            cur.lines.append(line)
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _analyze(comp: Computation) -> None:
    for line in comp.lines:
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        wm = re.search(r"\bwhile\(.*?\)", rhs)
        if wm and "condition=" in rhs and "body=" in rhs:
            body = re.search(r"body=%?([\w.\-]+)", rhs)
            cond = re.search(r"condition=%?([\w.\-]+)", rhs)
            if body and cond:
                comp.whiles.append((body.group(1), cond.group(1)))
            continue
        cm = re.search(r"\bcall\(.*?\)", rhs)
        if cm:
            to = re.search(r"to_apply=%?([\w.\-]+)", rhs)
            if to:
                comp.calls.append(to.group(1))
        for coll in _COLLECTIVES:
            m = re.search(rf"\b{coll}(-start|-done)?\(", rhs)
            if m:
                if m.group(1) == "-done":
                    break
                comp.collective_bytes[coll] = (
                    comp.collective_bytes.get(coll, 0) + _shape_bytes(rhs[: m.start()])
                )
                break


def _trip_count(cond: Computation) -> int:
    """Max int constant in the condition computation (exact for lax.scan)."""
    best = 1
    for line in cond.lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def collective_bytes_corrected(hlo: str) -> dict[str, int]:
    comps = _split_computations(hlo)
    for c in {id(c): c for c in comps.values()}.values():  # dedupe __entry__ alias
        _analyze(c)
    entry = comps.get("__entry__")
    totals: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    flat: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}

    for key, c in comps.items():
        if key == "__entry__":  # alias of the entry computation
            continue
        for k, v in c.collective_bytes.items():
            flat[k] += v

    seen: set[tuple[str, int]] = set()

    def walk(comp: Computation, mult: int, depth: int = 0):
        if depth > 16:
            return
        key = (comp.name, mult)
        if key in seen:
            return
        seen.add(key)
        for k, v in comp.collective_bytes.items():
            totals[k] += v * mult
        for body, cond in comp.whiles:
            trip = _trip_count(comps[cond]) if cond in comps else 1
            if body in comps:
                walk(comps[body], mult * max(trip, 1), depth + 1)
        for callee in comp.calls:
            if callee in comps:
                walk(comps[callee], mult, depth + 1)

    if entry is not None:
        walk(entry, 1)
    else:  # fallback: flat counting
        totals = dict(flat)

    out = {k: int(v) for k, v in totals.items()}
    out["total"] = int(sum(totals.values()))
    out["flat_total"] = int(sum(flat.values()))
    return out
