"""Sharding-aware pytree checkpointing on .npz (no external deps).

Leaves are flattened to 'path' keys via the same path encoding used by the
optimizer partition rules, gathered to host, and written atomically. Restore
rebuilds the exact tree structure from a template (or from the stored paths)
and re-places leaves under the caller's shardings via device_put.

Crash safety (the durable half of the recovery subsystem):

* every leaf carries a CRC32 in the meta record; :func:`load_checkpoint`
  verifies them on restore, so silent on-disk corruption (bit rot, torn
  writes that survived the rename) raises :class:`CheckpointError` instead
  of feeding garbage into the optimizer;
* writes are fsync-before-rename durable: the tmp file is fsynced before
  ``os.replace`` and the containing directory is fsynced after, so a host
  crash immediately after the rename cannot leave a zero-length
  "checkpoint" behind on journaled filesystems;
* :func:`save_round_checkpoint` writes round-stamped ``ckpt_<round>.npz``
  files under a keep-newest-N retention policy with an atomically-rewritten
  ``LATEST`` manifest, and :func:`load_latest_valid` walks newest -> oldest
  past truncated / corrupt / checksum-failing files — the ``--resume auto``
  loader never trusts a file it has not fully verified.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import tree_leaves_with_paths

PyTree = Any

_META = "__tree_meta__"
_CKPT_RE = re.compile(r"^ckpt_(\d+)\.npz$")
LATEST_MANIFEST = "LATEST"


class CheckpointError(RuntimeError):
    """A checkpoint file failed verification (truncated, corrupt, or a leaf
    checksum mismatch). :func:`load_latest_valid` treats it — like any I/O
    or parse failure — as "this file is invalid, fall back to the previous
    one"; direct :func:`load_checkpoint` callers see it raised."""


def _fsync_dir(dirname: str) -> None:
    """fsync the directory entry so a rename/create survives a host crash."""
    try:
        fd = os.open(dirname or ".", os.O_RDONLY)
    except OSError:
        return  # platform without directory fds: best effort
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _write_atomic(path: str, write_fn) -> None:
    """tmp-file -> ``write_fn(f)`` -> flush -> fsync -> rename -> dir fsync."""
    dirname = os.path.dirname(path) or "."
    os.makedirs(dirname, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=dirname, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(dirname)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def save_checkpoint(path: str, tree: PyTree, step: int = 0) -> None:
    flat = tree_leaves_with_paths(tree)
    arrays = {}
    meta = {"step": step, "paths": [], "dtypes": [], "crc32": []}
    for i, (p, leaf) in enumerate(flat):
        key = f"leaf_{i}"
        arr = np.asarray(jax.device_get(leaf))
        meta["dtypes"].append(str(arr.dtype))
        if arr.dtype.kind == "V":  # ml_dtypes (bfloat16 etc): store as raw bits
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        arrays[key] = arr
        meta["paths"].append(p)
        # checksum the stored representation (post bit-view) so verification
        # reads exactly what np.load hands back
        meta["crc32"].append(zlib.crc32(np.ascontiguousarray(arr).tobytes()))

    def write(f):
        np.savez(f, **arrays,
                 **{_META: np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8)})

    _write_atomic(path, write)


def load_checkpoint(path: str, template: PyTree, shardings: PyTree | None = None,
                    verify: bool = True) -> tuple[PyTree, int]:
    """Restore into the structure of ``template`` (validates paths match).

    ``verify=True`` (the default) recomputes every leaf's CRC32 against the
    checksums stored at save time and raises :class:`CheckpointError` on any
    mismatch — a checkpoint is either verified whole or not loaded at all.
    Pre-checksum checkpoints (no ``crc32`` meta) load without verification.
    """
    import ml_dtypes  # numpy extension dtypes (bfloat16) shipped with jax

    if os.path.getsize(path) == 0:
        # a crashed writer on a non-journaled fs can leave a zero-length
        # file where the rename landed; classify, don't explode in np.load
        raise CheckpointError(f"{path}: zero-length checkpoint file")
    try:
        with np.load(path) as z:
            meta = json.loads(bytes(z[_META]).decode())
            crcs = meta.get("crc32")
            arrays = []
            for i, dt in enumerate(meta.get("dtypes", [])):
                a = z[f"leaf_{i}"]
                if verify and crcs is not None:
                    got = zlib.crc32(np.ascontiguousarray(a).tobytes())
                    if got != crcs[i]:
                        raise CheckpointError(
                            f"{path}: leaf_{i} ({meta['paths'][i]}) checksum "
                            f"mismatch: stored {crcs[i]:#010x}, "
                            f"file has {got:#010x}")
                target = np.dtype(
                    getattr(ml_dtypes, dt, dt) if dt == "bfloat16" else dt)
                if a.dtype != target:
                    a = a.view(target)
                arrays.append(a)
            if not meta.get("dtypes"):
                arrays = [z[f"leaf_{i}"] for i in range(len(meta["paths"]))]
    except CheckpointError:
        raise
    except Exception as e:
        # truncated zips, flipped bits in zip structure or member payloads
        # (the zipfile layer CRC-checks too), unreadable meta: one unified
        # "this checkpoint is invalid" signal for callers to classify on
        raise CheckpointError(f"{path}: unreadable checkpoint ({e})") from e
    flat_t = tree_leaves_with_paths(template)
    t_paths = [p for p, _ in flat_t]
    if t_paths != meta["paths"]:
        # tolerate pure reorderings: dict states flattened in sorted-key
        # order, the TrainState dataclass flattens in field order — the same
        # leaves, permuted. Only a genuine set difference is an error.
        if sorted(t_paths) == sorted(meta["paths"]):
            by_path = {p: a for p, a in zip(meta["paths"], arrays)}
            arrays = [by_path[p] for p in t_paths]
        else:
            missing = [p for p in t_paths if p not in set(meta["paths"])]
            extra = [p for p in meta["paths"] if p not in set(t_paths)]
            raise ValueError(
                f"checkpoint tree mismatch: {len(meta['paths'])} stored leaves vs "
                f"{len(t_paths)} template leaves "
                f"(missing from checkpoint: {missing[:3]}; not in template: {extra[:3]})"
            )
    treedef = jax.tree.structure(template)
    if shardings is not None:
        shard_leaves = jax.tree.leaves(shardings)
        leaves = [jax.device_put(a, s) for a, s in zip(arrays, shard_leaves)]
    else:
        leaves = [jnp.asarray(a) for a in arrays]
    return jax.tree.unflatten(treedef, leaves), int(meta["step"])


# ---------------------------------------------------------------------------
# Round-stamped retention + the LATEST manifest + the auto-resume loader
# ---------------------------------------------------------------------------


def checkpoint_path(ckpt_dir: str, round: int) -> str:
    return os.path.join(ckpt_dir, f"ckpt_{round}.npz")


def list_checkpoints(ckpt_dir: str) -> list[tuple[int, str]]:
    """(round, path) for every round-stamped file, newest first."""
    if not os.path.isdir(ckpt_dir):
        return []
    found = []
    for name in os.listdir(ckpt_dir):
        m = _CKPT_RE.match(name)
        if m:
            found.append((int(m.group(1)), os.path.join(ckpt_dir, name)))
    return sorted(found, reverse=True)


def read_manifest(ckpt_dir: str) -> dict | None:
    """The LATEST manifest dict, or None when absent/unparseable (the walker
    never *trusts* the manifest — it is evidence for humans and tooling; the
    directory listing is the source of truth for auto-resume)."""
    path = os.path.join(ckpt_dir, LATEST_MANIFEST)
    try:
        with open(path, "rb") as f:
            return json.loads(f.read().decode())
    except (OSError, ValueError):
        return None


def _write_manifest(ckpt_dir: str, retained: list[tuple[int, str]]) -> None:
    manifest = {
        "latest": os.path.basename(retained[0][1]) if retained else None,
        "round": retained[0][0] if retained else None,
        "retained": [os.path.basename(p) for _, p in retained],
    }
    _write_atomic(os.path.join(ckpt_dir, LATEST_MANIFEST),
                  lambda f: f.write(json.dumps(manifest).encode()))


def save_round_checkpoint(ckpt_dir: str, tree: PyTree, round: int,
                          keep: int = 3) -> str:
    """Durably write ``ckpt_<round>.npz``, prune to the newest ``keep`` files,
    and atomically rewrite the ``LATEST`` manifest. Returns the path written.

    ``round`` is the number of completed rounds (the value of the state's
    on-device round counter), so a resume from this file starts at exactly
    that round. The prune never removes the file just written (``keep`` is
    clamped to >= 1), and the manifest is rewritten only after the prune so
    it always describes the files actually on disk.
    """
    path = checkpoint_path(ckpt_dir, round)
    save_checkpoint(path, tree, step=round)
    retained = list_checkpoints(ckpt_dir)
    keep = max(1, int(keep))
    for _, old in retained[keep:]:
        if os.path.abspath(old) != os.path.abspath(path):
            os.unlink(old)
    retained = retained[:keep]
    _write_manifest(ckpt_dir, retained)
    return path


def load_latest_valid(ckpt_dir: str, template: PyTree,
                      shardings: PyTree | None = None
                      ) -> tuple[PyTree, int, str] | None:
    """Walk the round-stamped checkpoints newest -> oldest and load the first
    one that fully verifies; returns ``(tree, round, path)`` or None when no
    valid checkpoint exists.

    Truncated files, zero-length files, corrupt zip/JSON structure, and leaf
    checksum mismatches are all classified as "invalid, fall back" — the
    resume path of a crashed run must make progress past whatever the crash
    left behind, not die on it. Tree mismatches (a checkpoint from a
    different config) are *also* skipped: an operator who changed the config
    mid-experiment should fall back to an older compatible file or a fresh
    start, not a stack trace.
    """
    skipped: list[str] = []
    for round, path in list_checkpoints(ckpt_dir):
        try:
            tree, step = load_checkpoint(path, template, shardings=shardings)
        except Exception as e:  # truncated/corrupt/mismatched: fall back
            skipped.append(f"{os.path.basename(path)} ({type(e).__name__}: {e})")
            continue
        if skipped:
            print(f"checkpoint: skipped {len(skipped)} invalid file(s): "
                  + "; ".join(skipped))
        return tree, step, path
    if skipped:
        print(f"checkpoint: no valid checkpoint in {ckpt_dir}; skipped: "
              + "; ".join(skipped))
    return None
