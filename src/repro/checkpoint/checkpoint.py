"""Sharding-aware pytree checkpointing on .npz (no external deps).

Leaves are flattened to 'path' keys via the same path encoding used by the
optimizer partition rules, gathered to host, and written atomically. Restore
rebuilds the exact tree structure from a template (or from the stored paths)
and re-places leaves under the caller's shardings via device_put.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import tree_leaves_with_paths

PyTree = Any

_META = "__tree_meta__"


def save_checkpoint(path: str, tree: PyTree, step: int = 0) -> None:
    flat = tree_leaves_with_paths(tree)
    arrays = {}
    meta = {"step": step, "paths": [], "dtypes": []}
    for i, (p, leaf) in enumerate(flat):
        key = f"leaf_{i}"
        arr = np.asarray(jax.device_get(leaf))
        meta["dtypes"].append(str(arr.dtype))
        if arr.dtype.kind == "V":  # ml_dtypes (bfloat16 etc): store as raw bits
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        arrays[key] = arr
        meta["paths"].append(p)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **arrays, **{_META: np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8)})
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_checkpoint(path: str, template: PyTree, shardings: PyTree | None = None) -> tuple[PyTree, int]:
    """Restore into the structure of ``template`` (validates paths match)."""
    import ml_dtypes  # numpy extension dtypes (bfloat16) shipped with jax

    with np.load(path) as z:
        meta = json.loads(bytes(z[_META]).decode())
        arrays = []
        for i, dt in enumerate(meta.get("dtypes", [])):
            a = z[f"leaf_{i}"]
            target = np.dtype(getattr(ml_dtypes, dt, dt) if dt == "bfloat16" else dt)
            if a.dtype != target:
                a = a.view(target)
            arrays.append(a)
        if not meta.get("dtypes"):
            arrays = [z[f"leaf_{i}"] for i in range(len(meta["paths"]))]
    flat_t = tree_leaves_with_paths(template)
    t_paths = [p for p, _ in flat_t]
    if t_paths != meta["paths"]:
        # tolerate pure reorderings: dict states flattened in sorted-key
        # order, the TrainState dataclass flattens in field order — the same
        # leaves, permuted. Only a genuine set difference is an error.
        if sorted(t_paths) == sorted(meta["paths"]):
            by_path = {p: a for p, a in zip(meta["paths"], arrays)}
            arrays = [by_path[p] for p in t_paths]
        else:
            missing = [p for p in t_paths if p not in set(meta["paths"])]
            extra = [p for p in meta["paths"] if p not in set(t_paths)]
            raise ValueError(
                f"checkpoint tree mismatch: {len(meta['paths'])} stored leaves vs "
                f"{len(t_paths)} template leaves "
                f"(missing from checkpoint: {missing[:3]}; not in template: {extra[:3]})"
            )
    treedef = jax.tree.structure(template)
    if shardings is not None:
        shard_leaves = jax.tree.leaves(shardings)
        leaves = [jax.device_put(a, s) for a, s in zip(arrays, shard_leaves)]
    else:
        leaves = [jnp.asarray(a) for a in arrays]
    return jax.tree.unflatten(treedef, leaves), int(meta["step"])
