from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointError,
    checkpoint_path,
    list_checkpoints,
    load_checkpoint,
    load_latest_valid,
    read_manifest,
    save_checkpoint,
    save_round_checkpoint,
)
