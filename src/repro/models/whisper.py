"""Whisper-large-v3 transformer backbone (arXiv:2212.04356).

Encoder–decoder: a bidirectional audio encoder over precomputed frame
embeddings (the mel-spectrogram + conv2 frontend is the permitted stub —
``input_specs`` supplies [B, n_audio_frames, d_model] directly) and a causal
text decoder with cross-attention. We keep the backbone faithful (MHA,
GELU FFN, pre-LN) but use RoPE in the decoder self-attention instead of
learned absolute positions (TPU-native choice, noted in DESIGN.md); the
encoder uses fixed sinusoidal embeddings as in the original.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (
    ModelConfig,
    dense_init,
    embed_init,
    rms_norm,
    shard_hint,
    sinusoidal_positions,
)
from repro.models.mlp import init_mlp, mlp

PyTree = Any


def init_whisper(key, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(key, 10)
    pd = cfg.pdtype
    Le = cfg.n_encoder_layers or cfg.n_layers
    Ld = cfg.n_layers
    enc_layers = {
        "attn": attn.init_attention(ks[0], cfg, n_layers=Le),
        "mlp": init_mlp(ks[1], cfg, n_layers=Le),
        "ln1_scale": jnp.zeros((Le, cfg.d_model), pd),
        "ln2_scale": jnp.zeros((Le, cfg.d_model), pd),
    }
    dec_layers = {
        "self_attn": attn.init_attention(ks[2], cfg, n_layers=Ld),
        "cross_attn": attn.init_attention(ks[3], cfg, n_layers=Ld),
        "mlp": init_mlp(ks[4], cfg, n_layers=Ld),
        "ln1_scale": jnp.zeros((Ld, cfg.d_model), pd),
        "ln2_scale": jnp.zeros((Ld, cfg.d_model), pd),
        "ln3_scale": jnp.zeros((Ld, cfg.d_model), pd),
    }
    return {
        "frontend_proj": dense_init(ks[5], (cfg.d_model, cfg.d_model), dtype=pd),  # conv stub -> d
        "encoder": {"layers": enc_layers, "final_norm_scale": jnp.zeros((cfg.d_model,), pd)},
        "embed": embed_init(ks[6], (cfg.vocab, cfg.d_model), dtype=pd),
        "decoder": {"layers": dec_layers, "final_norm_scale": jnp.zeros((cfg.d_model,), pd)},
        "head": dense_init(ks[7], (cfg.d_model, cfg.vocab), fan_in=cfg.d_model, dtype=pd),
    }


def encode(cfg: ModelConfig, params: PyTree, frames: jax.Array) -> jax.Array:
    """frames: [B, F, d] stub embeddings -> encoder states [B, F, d]."""
    dt = cfg.compute_dtype
    x = frames.astype(dt) @ params["frontend_proj"].astype(dt)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(dt)[None]
    positions = jnp.arange(x.shape[1])

    def body(x, lp):
        h = attn.attend(lp["attn"], cfg, rms_norm(x, lp["ln1_scale"]), positions, causal=False)
        x = x + h
        x = x + mlp(lp["mlp"], cfg, rms_norm(x, lp["ln2_scale"]))
        return shard_hint(x, "residual"), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["encoder"]["layers"])
    return rms_norm(x, params["encoder"]["final_norm_scale"])


def _dec_embed(cfg, params, tokens):
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    return x * jnp.asarray(math.sqrt(cfg.d_model), cfg.compute_dtype)


def forward_whisper(cfg: ModelConfig, params: PyTree, tokens: jax.Array,
                    context: jax.Array | None = None, last_only: bool = False,
                    hidden_only: bool = False, **_):
    """Training forward: context = audio frame embeddings [B, F, d]."""
    assert context is not None, "whisper forward requires audio context"
    enc = encode(cfg, params, context)
    x = _dec_embed(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1])

    def body(x, lp):
        h = attn.attend(lp["self_attn"], cfg, rms_norm(x, lp["ln1_scale"]), positions)
        x = x + h
        x = x + attn.cross_attend(lp["cross_attn"], cfg, rms_norm(x, lp["ln2_scale"]), enc)
        x = x + mlp(lp["mlp"], cfg, rms_norm(x, lp["ln3_scale"]))
        return shard_hint(x, "residual"), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["decoder"]["layers"])
    if last_only:
        x = x[:, -1:]
    x = rms_norm(x, params["decoder"]["final_norm_scale"])
    if hidden_only:
        return x, jnp.float32(0.0)
    return x @ params["head"].astype(cfg.compute_dtype), jnp.float32(0.0)


def init_cache_whisper(cfg: ModelConfig, params: PyTree, batch: int, cache_len: int) -> PyTree:
    Ld = cfg.n_layers
    KV, hd = cfg.n_kv_heads, cfg.hd
    return {
        "self": attn.init_cache(cfg, batch, cache_len, Ld),
        # cross K/V precomputed once from encoder output at request start
        "cross_k": jnp.zeros((Ld, batch, cfg.n_audio_frames, KV, hd), cfg.compute_dtype),
        "cross_v": jnp.zeros((Ld, batch, cfg.n_audio_frames, KV, hd), cfg.compute_dtype),
    }


def fill_context_whisper(cfg: ModelConfig, params: PyTree, cache: PyTree,
                         context: jax.Array) -> PyTree:
    """Condition a decode cache on the audio context: run the encoder once
    and precompute every decoder layer's cross-attention K/V.

    Without this the cross K/V buffers stay zero and decode silently runs
    unconditioned — the serving paths must call it (via
    ``Model.fill_context``) before the first decode step.
    """
    enc = encode(cfg, params, context)
    ca = params["decoder"]["layers"]["cross_attn"]
    k, v = jax.vmap(lambda lp: attn.cross_kv(lp, cfg, enc))(ca)
    return {**cache, "cross_k": k, "cross_v": v}


def decode_step_whisper(cfg: ModelConfig, params: PyTree, cache: PyTree, token: jax.Array,
                        pos: jax.Array, **_):
    x = _dec_embed(cfg, params, token[:, None])

    def body(x, inp):
        lp, self_cl, ck, cv = inp
        h_in = rms_norm(x, lp["ln1_scale"])
        h, new_self = attn.attend_decode(lp["self_attn"], cfg, h_in, self_cl, pos)
        x = x + h
        x = x + attn.cross_attend(lp["cross_attn"], cfg, rms_norm(x, lp["ln2_scale"]), (ck, cv))
        x = x + mlp(lp["mlp"], cfg, rms_norm(x, lp["ln3_scale"]))
        return x, new_self

    x, new_self = jax.lax.scan(
        body, x, (params["decoder"]["layers"], cache["self"], cache["cross_k"], cache["cross_v"])
    )
    x = rms_norm(x, params["decoder"]["final_norm_scale"])
    logits = (x @ params["head"].astype(cfg.compute_dtype))[:, 0]
    return logits, {"self": new_self, "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
