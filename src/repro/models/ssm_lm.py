"""Pure-SSM LM (mamba2-370m) and hybrid SSM+shared-attention LM (zamba2-2.7b).

zamba2: a stack of Mamba2 layers with ONE weight-shared transformer block
(GQA attention + MLP) invoked every ``hybrid_period`` layers (arXiv:2411.15242).
We scan over superblocks of ``hybrid_period`` mamba layers; the shared block's
params are closed over (not scanned), so its weights appear once in the pytree
— matching zamba's parameter sharing — while each invocation keeps its own KV
cache during decode.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import ModelConfig, dense_init, embed_init, rms_norm, shard_hint
from repro.models.mlp import init_mlp, mlp
from repro.models.ssm import init_mamba, init_ssm_state, mamba_decode, mamba_forward

PyTree = Any


# ---------------------------------------------------------------------------
# Pure Mamba2 LM
# ---------------------------------------------------------------------------


def init_ssm_lm(key, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(key, 3)
    L = cfg.n_layers
    pd = cfg.pdtype
    return {
        "embed": embed_init(ks[0], (cfg.vocab, cfg.d_model), dtype=pd),
        "layers": {
            "mamba": init_mamba(ks[1], cfg, n_layers=L),
            "ln_scale": jnp.zeros((L, cfg.d_model), pd),
        },
        "final_norm_scale": jnp.zeros((cfg.d_model,), pd),
        "head": dense_init(ks[2], (cfg.d_model, cfg.vocab), fan_in=cfg.d_model, dtype=pd),
    }


def _embed(cfg, params, tokens):
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    return x * jnp.asarray(math.sqrt(cfg.d_model), cfg.compute_dtype)


def _logits(cfg, params, x):
    x = rms_norm(x, params["final_norm_scale"])
    return x @ params["head"].astype(cfg.compute_dtype)


def forward_ssm_lm(cfg: ModelConfig, params: PyTree, tokens: jax.Array, last_only: bool = False,
                   hidden_only: bool = False, **_):
    x = _embed(cfg, params, tokens)

    def body(x, lp):
        h = mamba_forward(lp["mamba"], cfg, rms_norm(x, lp["ln_scale"]))
        return shard_hint(x + h, "residual"), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["layers"])
    if last_only:
        x = x[:, -1:]
    if hidden_only:
        return rms_norm(x, params["final_norm_scale"]), jnp.float32(0.0)
    return _logits(cfg, params, x), jnp.float32(0.0)


def init_cache_ssm_lm(cfg: ModelConfig, params: PyTree, batch: int, cache_len: int) -> PyTree:
    del cache_len  # O(1) state — the whole point of the SSM for long_500k
    return init_ssm_state(cfg, batch, cfg.n_layers)


def decode_step_ssm_lm(cfg: ModelConfig, params: PyTree, cache: PyTree, token: jax.Array,
                       pos: jax.Array, **_):
    del pos
    x = _embed(cfg, params, token[:, None])

    def body(x, inp):
        lp, st = inp
        h, st = mamba_decode(lp["mamba"], cfg, rms_norm(x, lp["ln_scale"]), st)
        return x + h, st

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    return _logits(cfg, params, x)[:, 0], new_cache


# ---------------------------------------------------------------------------
# Zamba2 hybrid
# ---------------------------------------------------------------------------


def _n_super(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.hybrid_period == 0, "n_layers must divide into superblocks"
    return cfg.n_layers // cfg.hybrid_period


def init_hybrid_lm(key, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(key, 6)
    pd = cfg.pdtype
    params = init_ssm_lm(ks[0], cfg)
    # reshape stacked mamba layers into [n_super, period, ...]
    ns, per = _n_super(cfg), cfg.hybrid_period
    params["layers"] = jax.tree.map(lambda x: x.reshape(ns, per, *x.shape[1:]), params["layers"])
    params["shared_block"] = {
        "attn": attn.init_attention(ks[1], cfg),
        "mlp": init_mlp(ks[2], cfg),
        "ln1_scale": jnp.zeros((cfg.d_model,), pd),
        "ln2_scale": jnp.zeros((cfg.d_model,), pd),
    }
    return params


def _shared_block_fwd(cfg, sp, x, positions):
    h = attn.attend(sp["attn"], cfg, rms_norm(x, sp["ln1_scale"]), positions)
    x = x + h
    return x + mlp(sp["mlp"], cfg, rms_norm(x, sp["ln2_scale"]))


def forward_hybrid_lm(cfg: ModelConfig, params: PyTree, tokens: jax.Array, last_only: bool = False,
                      hidden_only: bool = False, **_):
    x = _embed(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1])
    sp = params["shared_block"]

    def superblock(x, lp_group):
        x = _shared_block_fwd(cfg, sp, x, positions)

        def inner(x, lp):
            h = mamba_forward(lp["mamba"], cfg, rms_norm(x, lp["ln_scale"]))
            return x + h, None

        x, _ = jax.lax.scan(inner, x, lp_group)
        return shard_hint(x, "residual"), None

    body_fn = jax.checkpoint(superblock) if cfg.remat else superblock
    x, _ = jax.lax.scan(body_fn, x, params["layers"])
    if last_only:
        x = x[:, -1:]
    if hidden_only:
        return rms_norm(x, params["final_norm_scale"]), jnp.float32(0.0)
    return _logits(cfg, params, x), jnp.float32(0.0)


def init_cache_hybrid_lm(cfg: ModelConfig, params: PyTree, batch: int, cache_len: int) -> PyTree:
    ns = _n_super(cfg)
    if cfg.sliding_window:
        cache_len = min(cache_len, cfg.sliding_window)
    ssm = init_ssm_state(cfg, batch, cfg.n_layers)
    ssm = jax.tree.map(lambda x: x.reshape(ns, cfg.hybrid_period, *x.shape[1:]), ssm)
    return {"ssm": ssm, "attn": attn.init_cache(cfg, batch, cache_len, ns)}


def decode_step_hybrid_lm(cfg: ModelConfig, params: PyTree, cache: PyTree, token: jax.Array,
                          pos: jax.Array, **_):
    x = _embed(cfg, params, token[:, None])
    sp = params["shared_block"]

    def superblock(x, inp):
        lp_group, ssm_group, attn_cl = inp
        h_in = rms_norm(x, sp["ln1_scale"])
        h, new_attn_cl = attn.attend_decode(sp["attn"], cfg, h_in, attn_cl, pos)
        x = x + h
        x = x + mlp(sp["mlp"], cfg, rms_norm(x, sp["ln2_scale"]))

        def inner(x, inner_inp):
            lp, st = inner_inp
            h, st = mamba_decode(lp["mamba"], cfg, rms_norm(x, lp["ln_scale"]), st)
            return x + h, st

        x, new_ssm_group = jax.lax.scan(inner, x, (lp_group, ssm_group))
        return x, (new_ssm_group, new_attn_cl)

    x, (new_ssm, new_attn) = jax.lax.scan(
        superblock, x, (params["layers"], cache["ssm"], cache["attn"])
    )
    return _logits(cfg, params, x)[:, 0], {"ssm": new_ssm, "attn": new_attn}
