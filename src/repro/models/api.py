"""Unified model API: every architecture family exposes the same protocol.

    model = build_model(cfg)
    params = model.init(rng)
    logits, aux = model.forward(params, tokens, context=...)
    loss, metrics = model.loss(params, batch)
    cache = model.init_cache(params, batch_size, cache_len)
    logits, cache = model.decode_step(params, cache, token, pos)
    logits_last, cache = model.prefill(params, tokens, cache_len, context=...)

``batch`` is a dict: {"tokens": i32[B,S], "labels": i32[B,S],
optional "context": f[B,Sctx,d] (audio frames / image patches)}.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax

from repro.models import lm, ssm_lm, vlm, whisper
from repro.models.common import ModelConfig, fused_cross_entropy, softmax_cross_entropy

PyTree = Any

_FAMILIES: dict[str, dict[str, Callable]] = {
    "dense": {
        "init": lm.init_lm, "forward": lm.forward_lm,
        "init_cache": lm.init_cache_lm, "decode_step": lm.decode_step_lm,
        "prefill_cache": lm.prefill_with_cache_lm,
        "paged_prefill": lm.paged_prefill_lm, "paged_decode": lm.paged_decode_step_lm,
    },
    "moe": {
        "init": lm.init_lm, "forward": lm.forward_lm,
        "init_cache": lm.init_cache_lm, "decode_step": lm.decode_step_lm,
        "prefill_cache": lm.prefill_with_cache_lm,
        "paged_prefill": lm.paged_prefill_lm, "paged_decode": lm.paged_decode_step_lm,
    },
    "ssm": {
        "init": ssm_lm.init_ssm_lm, "forward": ssm_lm.forward_ssm_lm,
        "init_cache": ssm_lm.init_cache_ssm_lm, "decode_step": ssm_lm.decode_step_ssm_lm,
    },
    "hybrid": {
        "init": ssm_lm.init_hybrid_lm, "forward": ssm_lm.forward_hybrid_lm,
        "init_cache": ssm_lm.init_cache_hybrid_lm, "decode_step": ssm_lm.decode_step_hybrid_lm,
    },
    "audio": {
        "init": whisper.init_whisper, "forward": whisper.forward_whisper,
        "init_cache": whisper.init_cache_whisper, "decode_step": whisper.decode_step_whisper,
        "fill_context": whisper.fill_context_whisper,
    },
    "vlm": {
        "init": vlm.init_vlm, "forward": vlm.forward_vlm,
        "init_cache": vlm.init_cache_vlm, "decode_step": vlm.decode_step_vlm,
        "fill_context": vlm.fill_context_vlm,
    },
}


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def _fam(self):
        return _FAMILIES[self.cfg.arch_type]

    # --- params ---
    def init(self, rng: jax.Array) -> PyTree:
        return self._fam["init"](rng, self.cfg)

    def init_abstract(self) -> PyTree:
        """Parameter shapes without allocating (for dry-run sharding plans)."""
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # --- training ---
    def forward(self, params: PyTree, tokens: jax.Array, context: jax.Array | None = None,
                last_only: bool = False):
        return self._fam["forward"](self.cfg, params, tokens, context=context, last_only=last_only)

    def head_weight(self, params: PyTree) -> jax.Array:
        if self.cfg.tie_embeddings and "head" not in params:
            return params["embed"].T
        return params["head"]

    def loss(self, params: PyTree, batch: dict, fused: bool = True) -> tuple[jax.Array, dict]:
        """Training loss. ``fused`` uses the chunked head+CE (never
        materializes [B,S,V] logits); disabled automatically for softcap."""
        if fused and not self.cfg.logit_softcap:
            hidden, aux = self._fam["forward"](
                self.cfg, params, batch["tokens"], context=batch.get("context"),
                hidden_only=True)
            loss, metrics = fused_cross_entropy(hidden, self.head_weight(params),
                                                batch["labels"])
        else:
            logits, aux = self.forward(params, batch["tokens"], context=batch.get("context"))
            loss, metrics = softmax_cross_entropy(logits, batch["labels"])
        if self.cfg.n_experts and self.cfg.router_aux_coef:
            loss = loss + self.cfg.router_aux_coef * aux
            metrics["moe_aux"] = aux
        metrics["loss_total"] = loss
        return loss, metrics

    # --- serving ---
    def init_cache(self, params: PyTree, batch: int, cache_len: int) -> PyTree:
        return self._fam["init_cache"](self.cfg, params, batch, cache_len)

    def decode_step(self, params: PyTree, cache: PyTree, token: jax.Array, pos: jax.Array):
        return self._fam["decode_step"](self.cfg, params, cache, token, pos)

    def fill_context(self, params: PyTree, cache: PyTree, context: jax.Array) -> PyTree:
        """Condition a decode cache on the request context (audio frames /
        image patches). Families without cross-attention return the cache
        unchanged, so serving paths can call this unconditionally."""
        fn = self._fam.get("fill_context")
        return fn(self.cfg, params, cache, context) if fn is not None else cache

    @property
    def supports_batched_prefill(self) -> bool:
        """True when the family can fill a dense cache at every prompt
        position in ONE forward dispatch (attention-cache families);
        recurrent-state families prefill by stepping."""
        return "prefill_cache" in self._fam

    def prefill_with_cache(self, params: PyTree, cache: PyTree, tokens: jax.Array):
        """Batched prefill: (per-position logits [B, P, V], filled cache)."""
        return self._fam["prefill_cache"](self.cfg, params, cache, tokens)

    # --- paged serving (repro.serving; dense/moe families) ---
    @property
    def supports_paged_decode(self) -> bool:
        return "paged_decode" in self._fam

    def init_paged_cache(self, n_pages: int, page_size: int) -> PyTree:
        from repro.models import attention

        return attention.init_paged_cache(self.cfg, n_pages, page_size,
                                          self.cfg.n_layers)

    def paged_prefill(self, params: PyTree, cache: PyTree, tokens: jax.Array,
                      page_table: jax.Array, lengths: jax.Array):
        return self._fam["paged_prefill"](self.cfg, params, cache, tokens,
                                          page_table, lengths)

    def paged_decode_step(self, params: PyTree, cache: PyTree, token: jax.Array,
                          page_table: jax.Array, lengths: jax.Array,
                          impl: str = "xla"):
        return self._fam["paged_decode"](self.cfg, params, cache, token,
                                         page_table, lengths, impl=impl)

    def prefill(self, params: PyTree, tokens: jax.Array, context: jax.Array | None = None):
        """Full-sequence forward returning last-position logits only (the
        [B, S, V] logit tensor is never materialized; cache fill is
        family-specific and exercised via decode_step in tests)."""
        logits, _ = self.forward(params, tokens, context=context, last_only=True)
        return logits[:, -1]


def build_model(cfg: ModelConfig) -> Model:
    if cfg.arch_type not in _FAMILIES:
        raise ValueError(f"unknown arch_type {cfg.arch_type!r}")
    return Model(cfg)
