"""Mamba2 (state-space duality / SSD) block — arXiv:2405.21060.

Implements the chunked SSD algorithm: quadratic attention-like computation
inside chunks of length Q plus a linear recurrence over chunk states, which is
the TPU-friendly dual form (batched matmuls for the MXU + one short
``lax.scan``). Decode is the O(1)-per-token recurrent update on a
[B, H, P, N] state — this is why SSM archs run the 524k-token decode shape
natively.

Layout notes
  d_inner = expand * d_model, P = ssm_head_dim, H = d_inner / P heads,
  N = ssm_state, single B/C group (G=1) as in mamba2-370m.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, rms_norm

PyTree = Any


def init_mamba(key, cfg: ModelConfig, n_layers: int | None = None) -> PyTree:
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * N  # conv over (x, B, C)
    d_in_proj = 2 * di + 2 * N + H  # z, x, B, C, dt
    L = (n_layers,) if n_layers else ()
    ks = jax.random.split(key, 4)
    pd = cfg.pdtype
    return {
        "in_proj": dense_init(ks[0], (*L, d, d_in_proj), fan_in=d, dtype=pd),
        "conv_w": (jax.random.normal(ks[1], (*L, cfg.conv_width, conv_ch)) * 0.1).astype(pd),
        "conv_bias": jnp.zeros((*L, conv_ch), pd),
        "a_log": jnp.log(jnp.broadcast_to(jnp.linspace(1.0, 16.0, H), (*L, H))).astype(pd),
        "dt_bias": jnp.zeros((*L, H), pd),
        "d_skip": jnp.ones((*L, H), pd),
        "gate_norm_scale": jnp.zeros((*L, di), pd),
        "out_proj": dense_init(ks[3], (*L, di, d), fan_in=di, dtype=pd),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """x [..., Q] -> [..., Q, Q]; out[i, j] = sum_{j < k <= i} x[k], -inf for j > i."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. xBC [B,S,C]; w [W,C]; b [C]."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    S = xBC.shape[1]
    for i in range(W):  # W is tiny (4): unrolled taps
        out = out + pad[:, i : i + S, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xBC.dtype)


def _split_proj(p: PyTree, cfg: ModelConfig, x: jax.Array):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    return z, xBC, dt  # dt: [B, S, H]


def mamba_forward(p: PyTree, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Full-sequence chunked SSD. x: [B, S, d] with S % chunk == 0."""
    B, S, _ = x.shape
    di, N, H, P, Q = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_chunk
    assert S % Q == 0, f"seq {S} must be divisible by ssm_chunk {Q}"
    Cc = S // Q
    dt_compute = cfg.compute_dtype

    z, xBC, dt = _split_proj(p, cfg, x)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_bias"])
    xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)  # [B,S,di],[B,S,N],[B,S,N]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # [B,S,H]
    A = -jnp.exp(p["a_log"].astype(jnp.float32))  # [H]
    dA = dt * A  # [B,S,H]

    # chunk views
    xc = xs.reshape(B, Cc, Q, H, P).astype(jnp.float32)
    Bc = Bm.reshape(B, Cc, Q, N).astype(jnp.float32)
    Cm_c = Cm.reshape(B, Cc, Q, N).astype(jnp.float32)
    dA_c = dA.reshape(B, Cc, Q, H)
    dt_c = dt.reshape(B, Cc, Q, H)
    dAcum = jnp.cumsum(dA_c, axis=2)  # [B,Cc,Q,H]

    # --- intra-chunk (diagonal blocks) ---
    Lmat = jnp.exp(_segsum(jnp.swapaxes(dA_c, 2, 3)))  # [B,Cc,H,Q,Q]
    CB = jnp.einsum("bcin,bcjn->bcij", Cm_c, Bc)  # [B,Cc,Q,Q]
    M = CB[:, :, None] * Lmat  # [B,Cc,H,i,j]
    Y_diag = jnp.einsum("bchij,bcjh,bcjhp->bcihp", M, dt_c, xc)

    # --- chunk states ---
    decay_states = jnp.exp(dAcum[:, :, -1:, :] - dAcum)  # [B,Cc,Q,H]
    S_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", decay_states * dt_c, Bc, xc)  # [B,Cc,H,P,N]

    # --- inter-chunk recurrence (linear scan over chunk states) ---
    chunk_decay = jnp.exp(dAcum[:, :, -1, :])  # [B,Cc,H]

    def scan_fn(h, inp):
        s_c, g_c = inp  # state contribution + decay of this chunk
        h_out = h  # state *entering* the chunk
        h_next = g_c[..., None, None] * h + s_c
        return h_next, h_out

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, h_in = jax.lax.scan(
        scan_fn,
        h0,
        (jnp.moveaxis(S_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)  # [B,Cc,H,P,N], state entering each chunk

    state_decay = jnp.exp(dAcum)  # [B,Cc,Q,H]
    Y_off = jnp.einsum("bcin,bchpn,bcih->bcihp", Cm_c, h_in, state_decay)

    Y = (Y_diag + Y_off).reshape(B, S, H, P)
    x_heads = xs.reshape(B, S, H, P).astype(jnp.float32)
    Y = (Y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * x_heads).reshape(B, S, di)

    # gated RMSNorm + out projection
    Y = rms_norm((Y * jax.nn.silu(z.astype(jnp.float32))).astype(dt_compute), p["gate_norm_scale"])
    return Y @ p["out_proj"].astype(dt_compute)


def init_ssm_state(cfg: ModelConfig, batch: int, n_layers: int) -> PyTree:
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_ch = cfg.d_inner + 2 * N
    return {
        "h": jnp.zeros((n_layers, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, cfg.conv_width - 1, conv_ch), cfg.compute_dtype),
    }


def mamba_decode(p: PyTree, cfg: ModelConfig, x: jax.Array, state: PyTree) -> tuple[jax.Array, PyTree]:
    """One-token recurrent update. x: [B, 1, d]; state: {"h", "conv"} (per layer)."""
    B = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    dtc = cfg.compute_dtype

    z, xBC_new, dt = _split_proj(p, cfg, x)  # xBC_new [B,1,C]
    # rolling conv buffer: [B, W-1, C] previous inputs
    buf = jnp.concatenate([state["conv"], xBC_new.astype(state["conv"].dtype)], axis=1)  # [B,W,C]
    w = p["conv_w"].astype(jnp.float32)  # [W, C]
    conv_out = jnp.sum(buf.astype(jnp.float32) * w[None], axis=1, keepdims=True)  # [B,1,C]
    xBC = jax.nn.silu(conv_out + p["conv_bias"].astype(jnp.float32)).astype(dtc)
    new_conv = buf[:, 1:, :]

    xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # [B,H]
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    g = jnp.exp(dt * A)  # [B,H]

    xh = xs[:, 0].reshape(B, H, P).astype(jnp.float32)
    Bv = Bm[:, 0].astype(jnp.float32)  # [B,N]
    Cv = Cm[:, 0].astype(jnp.float32)
    h = state["h"] * g[..., None, None] + jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bv)
    y = jnp.einsum("bhpn,bn->bhp", h, Cv) + p["d_skip"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(B, 1, di)

    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(dtc), p["gate_norm_scale"])
    out = y @ p["out_proj"].astype(dtc)
    return out, {"h": h, "conv": new_conv}
