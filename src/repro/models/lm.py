"""Decoder-only language models (dense + MoE) with scan-over-layers.

Covers: mistral-large-123b, nemotron-4-15b (squared-ReLU), smollm-135m,
kimi-k2, deepseek-moe-16b, moonshot-v1-16b-a3b, and the paper's own
Gemma3-style scaling-ladder models (SwiGLU + QK-norm + post-norms).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import ModelConfig, dense_init, embed_init, rms_norm, shard_hint
from repro.models.mlp import init_mlp, init_moe, mlp, moe

PyTree = Any


def init_lm(key, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(key, 6)
    L = cfg.n_layers
    pd = cfg.pdtype
    layers = {
        "attn": attn.init_attention(ks[0], cfg, n_layers=L),
        "ln1_scale": jnp.zeros((L, cfg.d_model), pd),
        "ln2_scale": jnp.zeros((L, cfg.d_model), pd),
    }
    if cfg.post_norm:
        layers["ln1_post_scale"] = jnp.zeros((L, cfg.d_model), pd)
        layers["ln2_post_scale"] = jnp.zeros((L, cfg.d_model), pd)
    if cfg.n_experts:
        layers["moe"] = init_moe(ks[1], cfg, n_layers=L)
    else:
        layers["mlp"] = init_mlp(ks[1], cfg, n_layers=L)
    params = {
        "embed": embed_init(ks[2], (cfg.vocab, cfg.d_model), dtype=pd),
        "layers": layers,
        "final_norm_scale": jnp.zeros((cfg.d_model,), pd),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[3], (cfg.d_model, cfg.vocab), fan_in=cfg.d_model, dtype=pd)
    return params


def _block(cfg: ModelConfig, x: jax.Array, lp: PyTree, positions: jax.Array,
           return_kv: bool = False):
    """One transformer block. Returns (x, moe_aux) (+ the block's post-RoPE
    (k, v) when ``return_kv``, for cache-filling prefill)."""
    h = attn.attend(lp["attn"], cfg, rms_norm(x, lp["ln1_scale"]), positions,
                    return_kv=return_kv)
    kv = None
    if return_kv:
        h, kv = h
    if cfg.post_norm:
        h = rms_norm(h, lp["ln1_post_scale"])
    x = x + h
    x = shard_hint(x, "residual")
    hin = rms_norm(x, lp["ln2_scale"])
    if cfg.n_experts:
        h, aux = moe(lp["moe"], cfg, hin)
    else:
        h, aux = mlp(lp["mlp"], cfg, hin), jnp.float32(0.0)
    if cfg.post_norm:
        h = rms_norm(h, lp["ln2_post_scale"])
    if return_kv:
        return x + h, aux, kv
    return x + h, aux


def _embed(cfg: ModelConfig, params: PyTree, tokens: jax.Array) -> jax.Array:
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    return x * jnp.asarray(math.sqrt(cfg.d_model), cfg.compute_dtype)


def _logits(cfg: ModelConfig, params: PyTree, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm_scale"])
    head = params.get("head", None)
    w = head if head is not None else params["embed"].T
    logits = x @ w.astype(cfg.compute_dtype)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


def forward_lm(cfg: ModelConfig, params: PyTree, tokens: jax.Array, last_only: bool = False,
               hidden_only: bool = False, **_) -> tuple[jax.Array, jax.Array]:
    """Training / prefill forward. tokens [B, S] -> (logits [B,S,V], moe_aux).

    ``last_only`` returns logits for the final position only (prefill path:
    avoids materializing the [B, S, V] logit tensor)."""
    x = _embed(cfg, params, tokens)
    x = shard_hint(x, "residual")
    positions = jnp.arange(tokens.shape[1])

    def body(carry, lp):
        x, aux = carry
        x, a = _block(cfg, x, lp, positions)
        return (x, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), params["layers"])
    if last_only:
        x = x[:, -1:]
    if hidden_only:
        return rms_norm(x, params["final_norm_scale"]), aux
    return _logits(cfg, params, x), aux


def prefill_lm(cfg: ModelConfig, params: PyTree, tokens: jax.Array
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Batched prefill: ONE forward pass over the whole prompt that also
    emits every layer's post-RoPE K/V — the single-dispatch replacement for
    stepping ``decode_step`` token by token through the prompt.

    tokens [B, P] -> (logits [B, P, V], k [L, B, P, KV, hd], v [...]).
    """
    x = _embed(cfg, params, tokens)
    x = shard_hint(x, "residual")
    positions = jnp.arange(tokens.shape[1])

    def body(carry, lp):
        x, aux = carry
        x, a, kv = _block(cfg, x, lp, positions, return_kv=True)
        return (x, aux + a), kv

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, _), (k, v) = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), params["layers"])
    return _logits(cfg, params, x), k, v


def prefill_with_cache_lm(cfg: ModelConfig, params: PyTree, cache: PyTree,
                          tokens: jax.Array) -> tuple[jax.Array, PyTree]:
    """Single-dispatch prefill into a dense (``init_cache_lm``) cache.

    Returns (per-position logits [B, P, V], filled cache). With a sliding
    window the cache is the W-slot ring buffer, so only the last W prompt
    positions are written (at slot ``pos % W``) — exactly the state the
    token-stepping prefill would have left.
    """
    logits, k, v = prefill_lm(cfg, params, tokens)
    P = tokens.shape[1]
    W = cache["k"].shape[2]
    if cfg.sliding_window and W < P:
        pos = jnp.arange(P - W, P)
        ck = cache["k"].at[:, :, pos % W].set(k[:, :, P - W:])
        cv = cache["v"].at[:, :, pos % W].set(v[:, :, P - W:])
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0, 0))
    return logits, {"k": ck, "v": cv}


def paged_decode_step_lm(cfg: ModelConfig, params: PyTree, cache: PyTree,
                         token: jax.Array, page_table: jax.Array,
                         lengths: jax.Array, impl: str = "xla"
                         ) -> tuple[jax.Array, PyTree]:
    """One decode step against the paged KV pool (continuous batching).

    token [B] int32; cache from ``attention.init_paged_cache``; page_table
    [B, max_pages] int32; lengths [B] int32 (per-slot position of the new
    token). The layer scan mirrors :func:`decode_step_lm` with
    ``paged_attend_decode`` in place of ``attend_decode``.
    """
    x = _embed(cfg, params, token[:, None])

    def body(x, inp):
        lp, cl = inp
        h_in = rms_norm(x, lp["ln1_scale"])
        h, new_cl = attn.paged_attend_decode(lp["attn"], cfg, h_in, cl,
                                             page_table, lengths, impl=impl)
        if cfg.post_norm:
            h = rms_norm(h, lp["ln1_post_scale"])
        x = x + h
        hin = rms_norm(x, lp["ln2_scale"])
        if cfg.n_experts:
            h, _ = moe(lp["moe"], cfg, hin)
        else:
            h = mlp(lp["mlp"], cfg, hin)
        if cfg.post_norm:
            h = rms_norm(h, lp["ln2_post_scale"])
        return x + h, new_cl

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    return _logits(cfg, params, x)[:, 0], new_cache


def paged_prefill_lm(cfg: ModelConfig, params: PyTree, cache: PyTree,
                     tokens: jax.Array, page_table: jax.Array,
                     lengths: jax.Array) -> tuple[jax.Array, PyTree]:
    """Single-dispatch batched prefill into the paged pool.

    tokens [B, P] (right-padded to the admitted group's max prompt length;
    ``lengths`` holds each row's true prompt length) -> (logits [B, P, V],
    cache with every valid prompt position written to its page).
    """
    logits, k, v = prefill_lm(cfg, params, tokens)

    # scan over layers to keep memory flat (matches the decode-step scan)
    def body(_, inp):
        cl, k_l, v_l = inp
        return None, attn.fill_paged_cache(cl, k_l, v_l, page_table, lengths)

    _, new_cache = jax.lax.scan(body, None, (cache, k, v))
    return logits, new_cache


def init_cache_lm(cfg: ModelConfig, params: PyTree, batch: int, cache_len: int) -> PyTree:
    if cfg.sliding_window:
        cache_len = min(cache_len, cfg.sliding_window)
    return attn.init_cache(cfg, batch, cache_len, cfg.n_layers)


def decode_step_lm(cfg: ModelConfig, params: PyTree, cache: PyTree, token: jax.Array,
                   pos: jax.Array, **_) -> tuple[jax.Array, PyTree]:
    """One decode step. token [B] int32; cache from init_cache_lm; pos i32[]."""
    x = _embed(cfg, params, token[:, None])

    def body(x, inp):
        lp, cl = inp
        h_in = rms_norm(x, lp["ln1_scale"])
        h, new_cl = attn.attend_decode(lp["attn"], cfg, h_in, cl, pos)
        if cfg.post_norm:
            h = rms_norm(h, lp["ln1_post_scale"])
        x = x + h
        hin = rms_norm(x, lp["ln2_scale"])
        if cfg.n_experts:
            h, _ = moe(lp["moe"], cfg, hin)
        else:
            h = mlp(lp["mlp"], cfg, hin)
        if cfg.post_norm:
            h = rms_norm(h, lp["ln2_post_scale"])
        return x + h, new_cl

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    return _logits(cfg, params, x)[:, 0], new_cache
