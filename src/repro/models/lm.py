"""Decoder-only language models (dense + MoE) with scan-over-layers.

Covers: mistral-large-123b, nemotron-4-15b (squared-ReLU), smollm-135m,
kimi-k2, deepseek-moe-16b, moonshot-v1-16b-a3b, and the paper's own
Gemma3-style scaling-ladder models (SwiGLU + QK-norm + post-norms).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import ModelConfig, dense_init, embed_init, rms_norm, shard_hint
from repro.models.mlp import init_mlp, init_moe, mlp, moe

PyTree = Any


def init_lm(key, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(key, 6)
    L = cfg.n_layers
    pd = cfg.pdtype
    layers = {
        "attn": attn.init_attention(ks[0], cfg, n_layers=L),
        "ln1_scale": jnp.zeros((L, cfg.d_model), pd),
        "ln2_scale": jnp.zeros((L, cfg.d_model), pd),
    }
    if cfg.post_norm:
        layers["ln1_post_scale"] = jnp.zeros((L, cfg.d_model), pd)
        layers["ln2_post_scale"] = jnp.zeros((L, cfg.d_model), pd)
    if cfg.n_experts:
        layers["moe"] = init_moe(ks[1], cfg, n_layers=L)
    else:
        layers["mlp"] = init_mlp(ks[1], cfg, n_layers=L)
    params = {
        "embed": embed_init(ks[2], (cfg.vocab, cfg.d_model), dtype=pd),
        "layers": layers,
        "final_norm_scale": jnp.zeros((cfg.d_model,), pd),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[3], (cfg.d_model, cfg.vocab), fan_in=cfg.d_model, dtype=pd)
    return params


def _block(cfg: ModelConfig, x: jax.Array, lp: PyTree, positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One transformer block. Returns (x, moe_aux)."""
    h = attn.attend(lp["attn"], cfg, rms_norm(x, lp["ln1_scale"]), positions)
    if cfg.post_norm:
        h = rms_norm(h, lp["ln1_post_scale"])
    x = x + h
    x = shard_hint(x, "residual")
    hin = rms_norm(x, lp["ln2_scale"])
    if cfg.n_experts:
        h, aux = moe(lp["moe"], cfg, hin)
    else:
        h, aux = mlp(lp["mlp"], cfg, hin), jnp.float32(0.0)
    if cfg.post_norm:
        h = rms_norm(h, lp["ln2_post_scale"])
    return x + h, aux


def _embed(cfg: ModelConfig, params: PyTree, tokens: jax.Array) -> jax.Array:
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    return x * jnp.asarray(math.sqrt(cfg.d_model), cfg.compute_dtype)


def _logits(cfg: ModelConfig, params: PyTree, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm_scale"])
    head = params.get("head", None)
    w = head if head is not None else params["embed"].T
    logits = x @ w.astype(cfg.compute_dtype)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


def forward_lm(cfg: ModelConfig, params: PyTree, tokens: jax.Array, last_only: bool = False,
               hidden_only: bool = False, **_) -> tuple[jax.Array, jax.Array]:
    """Training / prefill forward. tokens [B, S] -> (logits [B,S,V], moe_aux).

    ``last_only`` returns logits for the final position only (prefill path:
    avoids materializing the [B, S, V] logit tensor)."""
    x = _embed(cfg, params, tokens)
    x = shard_hint(x, "residual")
    positions = jnp.arange(tokens.shape[1])

    def body(carry, lp):
        x, aux = carry
        x, a = _block(cfg, x, lp, positions)
        return (x, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), params["layers"])
    if last_only:
        x = x[:, -1:]
    if hidden_only:
        return rms_norm(x, params["final_norm_scale"]), aux
    return _logits(cfg, params, x), aux


def init_cache_lm(cfg: ModelConfig, params: PyTree, batch: int, cache_len: int) -> PyTree:
    if cfg.sliding_window:
        cache_len = min(cache_len, cfg.sliding_window)
    return attn.init_cache(cfg, batch, cache_len, cfg.n_layers)


def decode_step_lm(cfg: ModelConfig, params: PyTree, cache: PyTree, token: jax.Array,
                   pos: jax.Array, **_) -> tuple[jax.Array, PyTree]:
    """One decode step. token [B] int32; cache from init_cache_lm; pos i32[]."""
    x = _embed(cfg, params, token[:, None])

    def body(x, inp):
        lp, cl = inp
        h_in = rms_norm(x, lp["ln1_scale"])
        h, new_cl = attn.attend_decode(lp["attn"], cfg, h_in, cl, pos)
        if cfg.post_norm:
            h = rms_norm(h, lp["ln1_post_scale"])
        x = x + h
        hin = rms_norm(x, lp["ln2_scale"])
        if cfg.n_experts:
            h, _ = moe(lp["moe"], cfg, hin)
        else:
            h = mlp(lp["mlp"], cfg, hin)
        if cfg.post_norm:
            h = rms_norm(h, lp["ln2_post_scale"])
        return x + h, new_cl

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    return _logits(cfg, params, x)[:, 0], new_cache
