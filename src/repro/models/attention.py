"""Grouped-query attention with RoPE, QK-norm, sliding-window and KV caches.

Three entry points:
  * ``attend``            — full-sequence (training / prefill)
  * ``attend_decode``     — one new token against a [B, S, KV, hd] cache
  * ``cross_attend``      — encoder-decoder / VLM cross attention

Caches are plain dicts so they shard like any other pytree:
  full cache:   {"k": [B, S, KV, hd], "v": ..., "pos": i32[]}
  ring cache:   same but S == sliding window; slot = pos % window (used for
                long-context decode so dense archs stay sub-quadratic).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, apply_rope, dense_init, rms_norm, shard_hint

PyTree = Any
NEG_INF = -2.0e38


def init_attention(key, cfg: ModelConfig, n_layers: int | None = None, cross: bool = False) -> PyTree:
    """Attention params; stacked over n_layers when given (leading L axis)."""
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    L = (n_layers,) if n_layers else ()
    ks = jax.random.split(key, 4)
    pd = cfg.pdtype
    params = {
        "wq": dense_init(ks[0], (*L, d, H * hd), fan_in=d, dtype=pd),
        "wk": dense_init(ks[1], (*L, d, KV * hd), fan_in=d, dtype=pd),
        "wv": dense_init(ks[2], (*L, d, KV * hd), fan_in=d, dtype=pd),
        "wo": dense_init(ks[3], (*L, H * hd, d), fan_in=H * hd, dtype=pd),
    }
    if cfg.qk_norm:
        params["q_norm_scale"] = jnp.zeros((*L, hd), pd)
        params["k_norm_scale"] = jnp.zeros((*L, hd), pd)
    if cross:
        params["gate"] = jnp.zeros((*L,), pd)  # llama-3.2-vision tanh gate
    return params


def _project_qkv(p: PyTree, cfg: ModelConfig, x: jax.Array, kv_x: jax.Array):
    """Project to q [B,S,H,hd], k/v [B,Skv,KV,hd] with optional QK-norm."""
    B, S, _ = x.shape
    Skv = kv_x.shape[1]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.compute_dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, H, hd)
    k = (kv_x @ p["wk"].astype(dt)).reshape(B, Skv, KV, hd)
    v = (kv_x @ p["wv"].astype(dt)).reshape(B, Skv, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm_scale"])
        k = rms_norm(k, p["k_norm_scale"])
    return q, k, v


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q [B,Sq,H,hd] x k [B,Sk,KV,hd] -> scores [B,KV,G,Sq,Sk] with G=H/KV."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k) / jnp.sqrt(jnp.asarray(hd, jnp.float32)).astype(q.dtype)
    return s


def _gqa_out(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs [B,KV,G,Sq,Sk] x v [B,Sk,KV,hd] -> [B,Sq,H*hd]."""
    B, KV, G, Sq, Sk = probs.shape
    hd = v.shape[-1]
    o = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return o.reshape(B, Sq, KV * G * hd)


# default for ModelConfig.blockwise_threshold (kept as a module constant for
# external callers; the config field is what `attend` consults)
BLOCKWISE_THRESHOLD = 4096


def attend(p: PyTree, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
           causal: bool = True, return_kv: bool = False):
    """Full-sequence self-attention (training / prefill).

    Backend dispatch (``cfg.attn_impl``):

    * ``'pallas'`` — the fused flash-attention kernel
      (:func:`repro.kernels.flash_attention.gqa_flash_attention`): GQA-native
      blocked online softmax with full-block skipping and a flash-style
      custom VJP. Interpret mode off-TPU. On a mesh the StepPlan machinery
      routes the call through shard_map (batch x kv-heads over
      'data' x 'model', see :func:`repro.launch.sharding.kernel_specs`), so
      'pallas' lowers on multi-device worlds too.
    * ``'xla'`` (default) — dense O(S^2) softmax below
      ``cfg.blockwise_threshold``; above it, a blockwise online-softmax
      recurrence (lax.scan over kv blocks) that never materializes the
      score matrix and skips out-of-schedule blocks
      (:func:`repro.kernels.flash_attention.visited_kv_range`). Exact,
      differentiable, O(S * block) memory.

    Both non-dense paths assume rows attend by absolute position
    (``positions == arange(S)``, the training/prefill layout).

    ``return_kv=True`` additionally returns the post-RoPE ``(k, v)``
    projections ([B, S, KV, hd] each) — exactly what ``attend_decode``
    writes into its cache per token, so a single batched prefill forward
    can populate a KV cache at every prompt position at once (the serving
    prefill path).
    """
    q, k, v = _project_qkv(p, cfg, x, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_hint(q, "attn_kv")
    k = shard_hint(k, "attn_kv")
    v = shard_hint(v, "attn_kv")
    S = x.shape[1]
    B = x.shape[0]
    if cfg.attn_impl == "pallas":
        from repro.kernels.flash_attention import gqa_flash_attention

        o = gqa_flash_attention(
            q, k, v, causal=causal,
            window=cfg.sliding_window if causal else 0,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv)
        o = o.reshape(B, S, -1)
    elif S >= cfg.blockwise_threshold:
        o = _blockwise_attention(cfg, q, k, v, causal=causal,
                                 block_q=cfg.attn_block_q,
                                 block_kv=cfg.attn_block_kv)
        o = o.reshape(B, S, -1)
    else:
        scores = _gqa_scores(q, k).astype(jnp.float32)  # [B,KV,G,S,S]
        if causal:
            i = positions if positions.ndim == 1 else positions[0]
            mask = i[:, None] >= i[None, :]
            if cfg.sliding_window:
                mask &= i[:, None] - i[None, :] < cfg.sliding_window
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        probs = shard_hint(probs, "attn_probs")
        o = _gqa_out(probs, v)
    out = o @ p["wo"].astype(cfg.compute_dtype)
    if return_kv:
        return out, (k, v)
    return out


def _blockwise_attention(cfg: ModelConfig, q: jax.Array, k: jax.Array, v: jax.Array,
                         causal: bool, block_q: int = 512, block_kv: int = 1024,
                         skip_blocks: bool = True) -> jax.Array:
    """Exact attention via the online-softmax recurrence over KV blocks.

    q [B,S,H,hd], k/v [B,S,KV,hd] -> o [B,S,H,hd]. Memory per step is
    O(block_q * block_kv) instead of O(S^2). Each q block scans only its
    *visit schedule* — the contiguous kv-block range below the causal
    diagonal and inside the sliding window
    (:func:`repro.kernels.flash_attention.visited_kv_range`, the same
    schedule the Pallas kernel grids over) — so out-of-window and
    above-diagonal blocks are never computed. Skipping is bitwise-exact:
    a fully-masked block contributes exactly zero to (m, l, acc)
    (``skip_blocks=False`` forces the full sweep; pinned by
    tests/test_attention.py).
    """
    from repro.kernels.flash_attention import visited_kv_range

    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    bq = min(block_q, S)
    bkv = min(block_kv, S)
    nq, nkv = S // bq, S // bkv
    assert S % bq == 0 and S % bkv == 0
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    window = cfg.sliding_window if causal else 0

    qb = q.reshape(B, nq, bq, KV, G, hd)
    kb = k.reshape(B, nkv, bkv, KV, hd)
    vb = v.reshape(B, nkv, bkv, KV, hd)

    def make_q_block(qi: int, kj_lo: int, kj_hi: int):
        # qi and the kv range are static per q block (the schedule), so the
        # scan trip count is exactly the visited-block count.
        @jax.checkpoint  # backward recomputes the kv scan: O(block) residuals,
        def q_block(q_i):  # not O(S * block) saved probs per q block
            # q_i: [B, bq, KV, G, hd]
            q32 = q_i.astype(jnp.float32)

            def kv_step(carry, kj):
                m, l, acc = carry
                k_j = jax.lax.dynamic_index_in_dim(kb, kj, axis=1, keepdims=False)
                v_j = jax.lax.dynamic_index_in_dim(vb, kj, axis=1, keepdims=False)
                s = jnp.einsum("bqkgh,bskh->bkgqs", q32, k_j.astype(jnp.float32)) * scale
                rows = qi * bq + jnp.arange(bq)
                cols = kj * bkv + jnp.arange(bkv)
                mask = jnp.ones((bq, bkv), bool)
                if causal:
                    mask &= rows[:, None] >= cols[None, :]
                if window:  # sliding window only applies under causal,
                    mask &= rows[:, None] - cols[None, :] < window
                    # matching the dense and pallas paths (and the schedule)
                s = jnp.where(mask[None, None, None], s, NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bkgqs,bskh->bkgqh", p, v_j.astype(jnp.float32))
                return (m_new, l_new, acc_new), None

            m0 = jnp.full((B, KV, G, bq), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, KV, G, bq), jnp.float32)
            a0 = jnp.zeros((B, KV, G, bq, hd), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          jnp.arange(kj_lo, kj_hi))
            out = acc / jnp.maximum(l, 1e-30)[..., None]  # [B,KV,G,bq,hd]
            return jnp.moveaxis(out, 3, 1)  # [B,bq,KV,G,hd]

        return q_block

    outs = []
    for qi in range(nq):
        lo, hi = ((0, nkv) if not skip_blocks else
                  visited_kv_range(qi, nkv, bq, bkv, causal, window))
        outs.append(make_q_block(qi, lo, hi)(qb[:, qi]))
    # outs: [nq, B, bq, KV, G, hd] -> [B, S, H, hd]
    o = jnp.moveaxis(jnp.stack(outs), 0, 1).reshape(B, S, KV, G, hd).astype(q.dtype)
    return o.reshape(B, S, H, hd)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, n_layers: int, dtype=None) -> PyTree:
    dt = dtype or cfg.compute_dtype
    KV, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((n_layers, batch, cache_len, KV, hd), dt),
        "v": jnp.zeros((n_layers, batch, cache_len, KV, hd), dt),
    }


def fill_cache_from_prefill(k: jax.Array, v: jax.Array, cache_layer: PyTree) -> PyTree:
    """Write full-seq prefill K/V into the (larger) cache buffers."""
    ck = jax.lax.dynamic_update_slice(cache_layer["k"], k, (0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_layer["v"], v, (0, 0, 0, 0))
    return {"k": ck, "v": cv}


def attend_decode(p: PyTree, cfg: ModelConfig, x: jax.Array, cache_layer: PyTree,
                  pos: jax.Array) -> tuple[jax.Array, PyTree]:
    """Decode one token. x: [B, 1, d]; cache k/v: [B, W, KV, hd]; pos: i32[].

    With ``cfg.sliding_window`` the cache is a ring buffer of size W=window
    (slot = pos % W) so long-context decode memory is O(window), the
    sub-quadratic variant used for the 500k-token shape. Without it, the
    cache holds absolute positions (W >= seq_len).
    """
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(p, cfg, x, x)
    posb = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, posb, cfg.rope_theta)
    k_new = apply_rope(k_new, posb, cfg.rope_theta)

    W = cache_layer["k"].shape[1]
    slot = pos % W if cfg.sliding_window else pos
    ck = jax.lax.dynamic_update_slice(cache_layer["k"], k_new, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_layer["v"], v_new, (0, slot, 0, 0))

    scores = _gqa_scores(q, ck).astype(jnp.float32)  # [B,KV,G,1,W]
    idx = jnp.arange(W)
    if cfg.sliding_window:
        # slot s currently holds absolute position p(s): the largest p <= pos
        # with p % W == s.
        slot_pos = pos - ((pos - idx) % W)
        valid = (slot_pos >= 0) & (slot_pos > pos - W)
    else:
        valid = idx <= pos
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = _gqa_out(probs, cv)
    out = o @ p["wo"].astype(cfg.compute_dtype)
    return out, {"k": ck, "v": cv}


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                     n_layers: int, dtype=None) -> PyTree:
    """Paged KV pool: ``n_pages`` fixed-size pages shared by all sequences.

    Layout ``[L, n_pages, page_size, KV, hd]`` — the layer axis leads so the
    decode scan threads one ``[n_pages, page_size, KV, hd]`` pool per layer,
    mirroring :func:`init_cache`'s ``[L, B, S, KV, hd]``. Page 0 is reserved
    as the null/garbage page (see ``repro.serving.paging``).
    """
    dt = dtype or cfg.compute_dtype
    KV, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((n_layers, n_pages, page_size, KV, hd), dt),
        "v": jnp.zeros((n_layers, n_pages, page_size, KV, hd), dt),
    }


def paged_attend_decode(p: PyTree, cfg: ModelConfig, x: jax.Array,
                        cache_layer: PyTree, page_table: jax.Array,
                        lengths: jax.Array, impl: str = "xla") -> tuple[jax.Array, PyTree]:
    """Decode one token per slot against a paged KV cache (one layer).

    x ``[B, 1, d]``; cache k/v ``[n_pages, page_size, KV, hd]``;
    ``page_table`` ``[B, max_pages]`` int32 (0-padded; page 0 is the null
    page); ``lengths`` ``[B]`` int32 — slot b's new token sits at position
    ``lengths[b]`` (so, unlike :func:`attend_decode`, every slot has its own
    position: continuous batching never runs in lockstep). Writes the new
    K/V into each slot's current page, then attends over the slot's own
    pages via :func:`repro.kernels.flash_attention.paged_decode_attention`.
    """
    B = x.shape[0]
    ps = cache_layer["k"].shape[1]
    max_pages = page_table.shape[1]
    q, k_new, v_new = _project_qkv(p, cfg, x, x)
    posb = lengths[:, None].astype(jnp.int32)  # [B, 1] per-slot positions
    q = apply_rope(q, posb, cfg.rope_theta)
    k_new = apply_rope(k_new, posb, cfg.rope_theta)

    # page/slot of the new token; the min() clamp keeps slots that decode
    # past their allocation (finished requests padding out a span) writing
    # into the null page instead of reading out of bounds
    page_of = jnp.minimum(lengths // ps, max_pages - 1)
    page_ids = jnp.take_along_axis(page_table, page_of[:, None], axis=1)[:, 0]
    slot = lengths % ps
    ck = cache_layer["k"].at[page_ids, slot].set(k_new[:, 0])
    cv = cache_layer["v"].at[page_ids, slot].set(v_new[:, 0])

    from repro.kernels.flash_attention import paged_decode_attention

    o = paged_decode_attention(q[:, 0], ck, cv, page_table, lengths + 1,
                               window=cfg.sliding_window, impl=impl)
    out = o.reshape(B, 1, -1) @ p["wo"].astype(cfg.compute_dtype)
    return out, {"k": ck, "v": cv}


def fill_paged_cache(cache_layer: PyTree, k: jax.Array, v: jax.Array,
                     page_table: jax.Array, lengths: jax.Array) -> PyTree:
    """Scatter batched-prefill K/V ([B, P, KV, hd]) into pages.

    Position t of slot b lands in page ``page_table[b, t // ps]`` at slot
    ``t % ps``; positions at or past ``lengths[b]`` (prompt padding) are
    redirected to the null page 0.
    """
    B, P = k.shape[:2]
    ps = cache_layer["k"].shape[1]
    max_pages = page_table.shape[1]
    pos = jnp.arange(P)[None, :]  # [1, P]
    page_of = jnp.minimum(pos // ps, max_pages - 1)
    page_ids = jnp.take_along_axis(page_table, page_of.repeat(B, 0), axis=1)
    page_ids = jnp.where(pos < lengths[:, None], page_ids, 0)  # [B, P]
    slot = (pos % ps).repeat(B, 0)
    ck = cache_layer["k"].at[page_ids.reshape(-1), slot.reshape(-1)].set(
        k.reshape(B * P, *k.shape[2:]))
    cv = cache_layer["v"].at[page_ids.reshape(-1), slot.reshape(-1)].set(
        v.reshape(B * P, *v.shape[2:]))
    return {"k": ck, "v": cv}


def cross_attend(p: PyTree, cfg: ModelConfig, x: jax.Array, kv: jax.Array | tuple,
                 gated: bool = False) -> jax.Array:
    """Cross attention to a context. kv: context states [B, Sk, d] or a
    precomputed (k, v) pair ([B, Sk, KV, hd] each) for cached decoding."""
    dt = cfg.compute_dtype
    B, Sq, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"].astype(dt)).reshape(B, Sq, H, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm_scale"])
    if isinstance(kv, tuple):
        k, v = kv
    else:
        Sk = kv.shape[1]
        k = (kv @ p["wk"].astype(dt)).reshape(B, Sk, KV, hd)
        v = (kv @ p["wv"].astype(dt)).reshape(B, Sk, KV, hd)
        if cfg.qk_norm:
            k = rms_norm(k, p["k_norm_scale"])
    scores = _gqa_scores(q, k).astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = _gqa_out(probs, v)
    out = o @ p["wo"].astype(dt)
    if gated:
        out = jnp.tanh(p["gate"].astype(jnp.float32)).astype(dt) * out
    return out


def cross_kv(p: PyTree, cfg: ModelConfig, context: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Precompute cross-attention K/V once per request (decode path)."""
    dt = cfg.compute_dtype
    B, Sk, _ = context.shape
    KV, hd = cfg.n_kv_heads, cfg.hd
    k = (context @ p["wk"].astype(dt)).reshape(B, Sk, KV, hd)
    v = (context @ p["wv"].astype(dt)).reshape(B, Sk, KV, hd)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm_scale"])
    return k, v
