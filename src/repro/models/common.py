"""Shared model-definition building blocks.

All models are pure functional pytrees: ``init(rng, cfg) -> params`` and
forward functions taking ``(cfg, params, ...)``. Layers are stored *stacked*
(leading ``[L, ...]`` axis) and iterated with ``jax.lax.scan`` so the HLO is
depth-independent — essential for compiling 88-100 layer production configs
on the dry-run host, and it is what makes per-layer streaming-DiLoCo
partitions a simple boolean mask over the L axis.
"""
from __future__ import annotations

import dataclasses
import math
from contextvars import ContextVar
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    arch_type: str = "dense"  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 512
    vocab: int = 1024
    head_dim: int = 0  # 0 -> d_model // n_heads
    activation: str = "swiglu"  # swiglu | relu2 | gelu
    qk_norm: bool = True
    post_norm: bool = False  # gemma3-style extra RMSNorm after sublayer outputs
    rope_theta: float = 1_000_000.0
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_groups: int = 16  # token groups (sharded over 'data') for dispatch locality
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    conv_width: int = 4
    # hybrid (zamba2): one *shared* attention block applied every hybrid_period layers
    hybrid_period: int = 6
    # vlm (llama-3.2-vision): cross-attn layer every vlm_period-th layer
    vlm_period: int = 5
    n_image_tokens: int = 1600
    # audio (whisper)
    n_audio_frames: int = 1500
    n_encoder_layers: int = 0
    # attention variant
    sliding_window: int = 0  # 0 = full causal attention
    # attention execution backend: 'xla' (dense below blockwise_threshold,
    # online-softmax blockwise above) or 'pallas' (fused flash-attention
    # kernel, interpret mode off-TPU; shard_mapped over the mesh by the
    # kernel-partitioning routing, so it lowers on multi-device worlds too)
    attn_impl: str = "xla"
    blockwise_threshold: int = 4096  # seqs >= this switch xla to blockwise
    attn_block_q: int = 512  # q-block rows per attention tile
    attn_block_kv: int = 1024  # kv-block rows per attention tile
    # training sequence length (0 = unspecified). The launchers plumb
    # --seq-len here so the model config is the single source of truth for
    # the data pipeline, and the sliding window is clamped to it.
    max_seq_len: int = 0
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    # provenance / applicability
    citation: str = ""
    skip_shapes: tuple = ()  # input shapes this arch skips (documented in DESIGN.md)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Activation sharding hints (hillclimbing lever; no-op unless rules installed)
# ---------------------------------------------------------------------------

_ACT_RULES: ContextVar[dict[str, P] | None] = ContextVar("act_rules", default=None)


class activation_sharding:
    """Context manager installing named activation sharding constraints.

    Example::

        with activation_sharding({"residual": P("data", None, "model")}):
            logits = forward(...)
    """

    def __init__(self, rules: dict[str, P]):
        self.rules = rules

    def __enter__(self):
        self._tok = _ACT_RULES.set(self.rules)
        return self

    def __exit__(self, *exc):
        _ACT_RULES.reset(self._tok)
        return False


def shard_hint(x: jax.Array, name: str) -> jax.Array:
    rules = _ACT_RULES.get()
    if rules is None or name not in rules:
        return x
    spec = rules[name]
    # right-align the spec with the value's rank (rules are written for the
    # canonical [B, S, ...] layout; lower-rank views drop leading axes)
    entries = list(spec)
    if len(entries) > x.ndim:
        entries = entries[len(entries) - x.ndim:]
    elif len(entries) < x.ndim:
        entries = [None] * (x.ndim - len(entries)) + entries
    return jax.lax.with_sharding_constraint(x, P(*entries))


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    normed = x32 * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def activation_fn(name: str, x: jax.Array, gate: jax.Array | None = None) -> jax.Array:
    if name == "swiglu":
        assert gate is not None
        return jax.nn.silu(gate) * x
    if name == "relu2":  # nemotron-4 squared ReLU
        return jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(f"unknown activation {name!r}")


def rope_frequencies(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [B, S, H, hd]; positions: [B, S] or [S]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # [hd/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, hd/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings [n, d]."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10000.0) * dim / max(d // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, fan_in: int | None = None, dtype=jnp.float32) -> jax.Array:
    fan = fan_in if fan_in is not None else shape[-2]
    std = 1.0 / math.sqrt(fan)
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32) -> jax.Array:
    # std 1/sqrt(d): with the sqrt(d) input scaling this keeps the residual
    # stream O(1) AND keeps tied-embedding logits O(1).
    std = 1.0 / math.sqrt(shape[-1])
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32) * std).astype(dtype)


def key_tree(key: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None,
                          z_loss: float = 0.0) -> tuple[jax.Array, dict]:
    """Mean next-token cross-entropy in fp32. logits [B,S,V], labels [B,S].

    Sharded-vocab-safe: the gold logit is gathered with a one-hot einsum
    (reduces locally over the 'model'-sharded vocab axis, then a scalar-sized
    all-reduce) instead of take_along_axis, which GSPMD can only lower by
    all-gathering the full fp32 logits. Max subtraction happens in-fusion so
    the fp32 logit tensor is never a standalone temp (§Perf iteration 1).
    """
    logits = logits.astype(jnp.float32)
    lmax = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
    logz = lmax + jnp.log(jnp.sum(jnp.exp(logits - lmax[..., None]), axis=-1))
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("...v,...v->...", logits, onehot)
    nll = logz - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(logz)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "tokens": jnp.sum(mask)}


def fused_cross_entropy(hidden: jax.Array, head_w: jax.Array, labels: jax.Array,
                        chunk: int = 512) -> tuple[jax.Array, dict]:
    """Head-matmul + cross-entropy fused per sequence chunk.

    The full [B, S, V] logit tensor is never materialized: each S-chunk's
    logits live only inside a rematerialized map step (fp32, [B, chunk, V]).
    This is the production big-vocab loss (§Perf iteration 1): peak memory
    drops from O(B*S*V) to O(B*chunk*V) and backward recomputes chunk logits
    instead of storing them.

    hidden: [B, S, d] post-final-norm states; head_w: [d, V]; labels: [B, S].
    """
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    n = S // chunk
    hc = hidden.reshape(B, n, chunk, d).swapaxes(0, 1)  # [n, B, chunk, d]
    lc = labels.reshape(B, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def one(x_c, y_c):
        logits = (x_c @ head_w.astype(x_c.dtype)).astype(jnp.float32)
        lmax = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
        logz = lmax + jnp.log(jnp.sum(jnp.exp(logits - lmax[..., None]), axis=-1))
        onehot = jax.nn.one_hot(y_c, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.einsum("...v,...v->...", logits, onehot)
        return jnp.sum(logz - gold)

    def scan_body(acc, xy):
        return acc + one(*xy), None

    total, _ = jax.lax.scan(scan_body, jnp.float32(0.0), (hc, lc))
    loss = total / (B * S)
    return loss, {"loss": loss, "tokens": jnp.float32(B * S)}
