"""Feed-forward blocks: dense (SwiGLU / squared-ReLU / GELU) and MoE.

The MoE uses capacity-based dispatch (scatter into an [E, C, d] buffer,
per-expert matmuls, gather-combine) rather than a dense [T, E] einsum so the
expert dimension can be sharded over the `model` mesh axis (expert
parallelism) and activation memory stays O(T * top_k * d) — required to fit
kimi-k2's 384-expert layers at the 1M-token training shape.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, activation_fn, dense_init, shard_hint

PyTree = Any


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, n_layers: int | None = None, d_ff: int | None = None) -> PyTree:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    L = (n_layers,) if n_layers else ()
    k1, k2, k3 = jax.random.split(key, 3)
    pd = cfg.pdtype
    params = {
        "w_in": dense_init(k1, (*L, d, ff), fan_in=d, dtype=pd),
        "w_out": dense_init(k2, (*L, ff, d), fan_in=ff, dtype=pd),
    }
    if cfg.activation == "swiglu":
        params["w_gate"] = dense_init(k3, (*L, d, ff), fan_in=d, dtype=pd)
    return params


def mlp(p: PyTree, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    dt = cfg.compute_dtype
    h = x @ p["w_in"].astype(dt)
    if cfg.activation == "swiglu":
        h = activation_fn("swiglu", h, x @ p["w_gate"].astype(dt))
    else:
        h = activation_fn(cfg.activation, h)
    h = shard_hint(h, "ffn_hidden")
    return h @ p["w_out"].astype(dt)


# ---------------------------------------------------------------------------
# Mixture of Experts (DeepSeekMoE-style: shared + fine-grained routed experts)
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig, n_layers: int | None = None) -> PyTree:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    L = (n_layers,) if n_layers else ()
    ks = jax.random.split(key, 5)
    pd = cfg.pdtype
    params = {
        "router": dense_init(ks[0], (*L, d, E), fan_in=d, dtype=pd),
        # routed experts: banked weights [*, E, d, ff]
        "experts": {
            "w_in": dense_init(ks[1], (*L, E, d, ff), fan_in=d, dtype=pd),
            "w_gate": dense_init(ks[2], (*L, E, d, ff), fan_in=d, dtype=pd),
            "w_out": dense_init(ks[3], (*L, E, ff, d), fan_in=ff, dtype=pd),
        },
    }
    if cfg.n_shared_experts:
        shared_ff = ff * cfg.n_shared_experts
        sk = jax.random.split(ks[4], 3)
        params["shared"] = {
            "w_in": dense_init(sk[0], (*L, d, shared_ff), fan_in=d, dtype=pd),
            "w_gate": dense_init(sk[1], (*L, d, shared_ff), fan_in=d, dtype=pd),
            "w_out": dense_init(sk[2], (*L, shared_ff, d), fan_in=shared_ff, dtype=pd),
        }
    return params


def _expert_ffn(w: PyTree, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Per-expert SwiGLU on dispatched tokens. x: [G, E, C, d]; weights [E, d, ff]."""
    dt = cfg.compute_dtype
    h = jnp.einsum("gecd,edf->gecf", x, w["w_in"].astype(dt))
    g = jnp.einsum("gecd,edf->gecf", x, w["w_gate"].astype(dt))
    h = jax.nn.silu(g) * h
    return jnp.einsum("gecf,efd->gecd", h, w["w_out"].astype(dt))


def _n_groups(cfg: ModelConfig, T: int) -> int:
    """Largest group count <= cfg.moe_groups that divides T (>=1)."""
    g = max(cfg.moe_groups, 1)
    while g > 1 and (T % g or T // g < cfg.experts_per_token):
        g -= 1
    return g


def moe(p: PyTree, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """MoE layer. x: [B, S, d] -> (out [B, S, d], aux load-balance loss).

    Grouped capacity dispatch: tokens are split into G groups (sharded over
    the `data` mesh axis) so the scatter/gather used for dispatch stays local
    to a shard — GSPMD shards batched scatters over the group axis, while a
    global flat scatter would replicate the [E*C, d] buffer on every chip
    (observed: 2.8 TiB/chip for kimi-k2 before this formulation).
    """
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.experts_per_token
    dt = cfg.compute_dtype
    G = _n_groups(cfg, T)
    t = T // G
    xg = x.reshape(G, t, d)
    xg = shard_hint(xg, "moe_tokens")

    logits = (xg @ p["router"].astype(dt)).astype(jnp.float32)  # [G, t, E]
    gates = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(gates, k)  # [G, t, k]
    # normalize selected gate weights (DeepSeekMoE)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)

    # ---- per-group capacity dispatch ----
    # Overflowed (token, slot) pairs scatter *zeros* into slot 0 instead of
    # using a +1 spill row: the slot dim stays a clean multiple so the
    # scatter keeps its d-passthrough / G-batch partitioning.
    C = max(int(t * k / E * cfg.capacity_factor), 4)
    flat_e = top_idx.reshape(G, t * k)  # expert id per (token, slot)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # [G, t*k, E]
    pos = jnp.cumsum(oh, axis=1) - 1  # running per-expert rank within group
    my_pos = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]  # [G, t*k]
    keep = my_pos < C
    # dropped pairs index out-of-bounds -> mode='drop'; destinations are
    # unique (kept: by construction; dropped: distinct OOB slots) so the
    # scatter has no combiner and GSPMD keeps its batch/passthrough
    # partitioning.
    oob = E * C + jnp.arange(t * k)[None, :]
    dest = jnp.where(keep, flat_e * C + jnp.clip(my_pos, 0, C - 1), oob)

    x_rep = jnp.repeat(xg, k, axis=1)  # [G, t*k, d]
    gidx = jnp.arange(G)[:, None]
    buf = jnp.zeros((G, E * C, d), dt).at[gidx, dest].set(
        x_rep, mode="drop", unique_indices=True)
    buf = shard_hint(buf, "moe_buffer")
    dispatched = shard_hint(buf.reshape(G, E, C, d), "moe_dispatch")

    y = _expert_ffn(p["experts"], cfg, dispatched)  # [G, E, C, d]

    # ---- combine ----
    y_flat = shard_hint(y.reshape(G, E * C, d), "moe_buffer")
    gather_dest = jnp.where(keep, dest, 0)  # dropped rows read slot 0, zeroed by w
    gathered = jnp.take_along_axis(y_flat, gather_dest[..., None], axis=1)  # [G, t*k, d]
    w = (top_vals.reshape(G, t * k) * keep.astype(jnp.float32)).astype(dt)
    out = jnp.sum((gathered * w[..., None]).reshape(G, t, k, d), axis=2)

    # shared experts are always-on dense FFNs
    if "shared" in p:
        shared_cfg = cfg.replace(activation="swiglu")
        out = out + mlp(p["shared"], shared_cfg, xg.reshape(T, d)).reshape(G, t, d)

    # Switch-style load balance aux: E * sum_e f_e * p_e (global)
    frac_tokens = jnp.mean(jax.nn.one_hot(top_idx, E, dtype=jnp.float32), axis=(0, 1, 2)) * k
    mean_gate = jnp.mean(gates, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * mean_gate)
    return out.reshape(B, S, d), aux
