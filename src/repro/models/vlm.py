"""Llama-3.2-Vision language backbone (hf:meta-llama/Llama-3.2-11B-Vision).

A causal LM where every ``vlm_period``-th layer is a *gated cross-attention*
block attending to image patch embeddings. The ViT/projector frontend is the
permitted stub — ``input_specs`` supplies [B, n_image_tokens, d_model]
directly. 100 layers at period 5 -> 20 superblocks of (1 cross + 4 self)
layers, scanned at the superblock level so HLO stays depth-independent.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import ModelConfig, dense_init, embed_init, rms_norm, shard_hint
from repro.models.mlp import init_mlp, mlp

PyTree = Any


def _blocks(cfg: ModelConfig) -> tuple[int, int]:
    assert cfg.n_layers % cfg.vlm_period == 0
    n_super = cfg.n_layers // cfg.vlm_period
    n_self_per = cfg.vlm_period - 1
    return n_super, n_self_per


def init_vlm(key, cfg: ModelConfig) -> PyTree:
    ns, per = _blocks(cfg)
    ks = jax.random.split(key, 8)
    pd = cfg.pdtype
    n_self = ns * per

    def self_stack(x):  # [n_self, ...] -> [ns, per, ...]
        return jax.tree.map(lambda a: a.reshape(ns, per, *a.shape[1:]), x)

    self_layers = self_stack({
        "attn": attn.init_attention(ks[0], cfg, n_layers=n_self),
        "mlp": init_mlp(ks[1], cfg, n_layers=n_self),
        "ln1_scale": jnp.zeros((n_self, cfg.d_model), pd),
        "ln2_scale": jnp.zeros((n_self, cfg.d_model), pd),
    })
    cross_layers = {
        "attn": attn.init_attention(ks[2], cfg, n_layers=ns, cross=True),
        "mlp": init_mlp(ks[3], cfg, n_layers=ns),
        "ln1_scale": jnp.zeros((ns, cfg.d_model), pd),
        "ln2_scale": jnp.zeros((ns, cfg.d_model), pd),
        "mlp_gate": jnp.zeros((ns,), pd),
    }
    return {
        "embed": embed_init(ks[4], (cfg.vocab, cfg.d_model), dtype=pd),
        "image_proj": dense_init(ks[5], (cfg.d_model, cfg.d_model), dtype=pd),  # projector stub
        "self_layers": self_layers,
        "cross_layers": cross_layers,
        "final_norm_scale": jnp.zeros((cfg.d_model,), pd),
        "head": dense_init(ks[6], (cfg.d_model, cfg.vocab), fan_in=cfg.d_model, dtype=pd),
    }


def _embed(cfg, params, tokens):
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    return x * jnp.asarray(math.sqrt(cfg.d_model), cfg.compute_dtype)


def _cross_block(cfg, cp, x, img):
    h = attn.cross_attend(cp["attn"], cfg, rms_norm(x, cp["ln1_scale"]), img, gated=True)
    x = x + h
    g = jnp.tanh(cp["mlp_gate"].astype(jnp.float32)).astype(x.dtype)
    return x + g * mlp(cp["mlp"], cfg, rms_norm(x, cp["ln2_scale"]))


def _self_block(cfg, lp, x, positions):
    h = attn.attend(lp["attn"], cfg, rms_norm(x, lp["ln1_scale"]), positions)
    x = x + h
    return x + mlp(lp["mlp"], cfg, rms_norm(x, lp["ln2_scale"]))


def forward_vlm(cfg: ModelConfig, params: PyTree, tokens: jax.Array,
                context: jax.Array | None = None, last_only: bool = False,
                hidden_only: bool = False, **_):
    """context = image patch embeddings [B, n_image_tokens, d_model] (stub)."""
    assert context is not None, "vlm forward requires image context"
    dt = cfg.compute_dtype
    img = context.astype(dt) @ params["image_proj"].astype(dt)
    x = _embed(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1])

    def superblock(x, inp):
        cp, sp_group = inp
        x = _cross_block(cfg, cp, x, img)

        def inner(x, lp):
            return _self_block(cfg, lp, x, positions), None

        x, _ = jax.lax.scan(inner, x, sp_group)
        return shard_hint(x, "residual"), None

    body_fn = jax.checkpoint(superblock) if cfg.remat else superblock
    x, _ = jax.lax.scan(body_fn, x, (params["cross_layers"], params["self_layers"]))
    if last_only:
        x = x[:, -1:]
    x = rms_norm(x, params["final_norm_scale"])
    if hidden_only:
        return x, jnp.float32(0.0)
    return x @ params["head"].astype(dt), jnp.float32(0.0)


def init_cache_vlm(cfg: ModelConfig, params: PyTree, batch: int, cache_len: int) -> PyTree:
    ns, per = _blocks(cfg)
    if cfg.sliding_window:
        cache_len = min(cache_len, cfg.sliding_window)
    self_cache = attn.init_cache(cfg, batch, cache_len, ns * per)
    self_cache = jax.tree.map(lambda a: a.reshape(ns, per, *a.shape[1:]), self_cache)
    KV, hd = cfg.n_kv_heads, cfg.hd
    return {
        "self": self_cache,
        "cross_k": jnp.zeros((ns, batch, cfg.n_image_tokens, KV, hd), cfg.compute_dtype),
        "cross_v": jnp.zeros((ns, batch, cfg.n_image_tokens, KV, hd), cfg.compute_dtype),
    }


def fill_context_vlm(cfg: ModelConfig, params: PyTree, cache: PyTree,
                     context: jax.Array) -> PyTree:
    """Condition a decode cache on the image context: project the patch
    embeddings and precompute every cross-attention superblock's K/V (the
    VLM analogue of ``fill_context_whisper``)."""
    dt = cfg.compute_dtype
    img = context.astype(dt) @ params["image_proj"].astype(dt)
    ca = params["cross_layers"]["attn"]
    k, v = jax.vmap(lambda lp: attn.cross_kv(lp, cfg, img))(ca)
    return {**cache, "cross_k": k, "cross_v": v}


def decode_step_vlm(cfg: ModelConfig, params: PyTree, cache: PyTree, token: jax.Array,
                    pos: jax.Array, **_):
    x = _embed(cfg, params, token[:, None])

    def superblock(x, inp):
        cp, sp_group, self_cl, ck, cv = inp
        h = attn.cross_attend(cp["attn"], cfg, rms_norm(x, cp["ln1_scale"]), (ck, cv), gated=True)
        x = x + h
        g = jnp.tanh(cp["mlp_gate"].astype(jnp.float32)).astype(x.dtype)
        x = x + g * mlp(cp["mlp"], cfg, rms_norm(x, cp["ln2_scale"]))

        def inner(x, inner_inp):
            lp, cl = inner_inp
            h_in = rms_norm(x, lp["ln1_scale"])
            h, new_cl = attn.attend_decode(lp["attn"], cfg, h_in, cl, pos)
            x = x + h
            return x + mlp(lp["mlp"], cfg, rms_norm(x, lp["ln2_scale"])), new_cl

        x, new_group = jax.lax.scan(inner, x, (sp_group, self_cl))
        return x, new_group

    x, new_self = jax.lax.scan(
        superblock, x,
        (params["cross_layers"], params["self_layers"], cache["self"], cache["cross_k"], cache["cross_v"]),
    )
    x = rms_norm(x, params["final_norm_scale"])
    logits = (x @ params["head"].astype(cfg.compute_dtype))[:, 0]
    return logits, {"self": new_self, "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
