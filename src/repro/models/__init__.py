from repro.models.api import Model, build_model  # noqa: F401
from repro.models.common import (  # noqa: F401
    ModelConfig,
    activation_sharding,
    rms_norm,
    shard_hint,
    softmax_cross_entropy,
)
