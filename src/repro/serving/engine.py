"""Continuous-batching serving engine over the paged KV cache.

The seed served requests in lockstep: one batch, token-by-token prefill,
every sequence padded to the longest, the whole batch held until the last
request finished. This engine replaces that with the standard
paged-attention design:

* :class:`PageAllocator` (``serving.paging``) owns a fixed pool of KV
  pages on the host; the device holds the page *contents*
  (``model.init_paged_cache``).
* :class:`Scheduler` admits pending requests into freed batch slots as
  soon as pages are available, and its admission check accounts for the
  worst-case remaining growth of every in-flight request, so
  allocate-on-demand (``PageAllocator.ensure``) can never fail mid-span.
* Admitted requests are prefilled in ONE batched dispatch
  (``model.paged_prefill``) instead of stepping the decode path through
  the prompt.
* Decode runs ``decode_steps_per_dispatch`` tokens for ALL active slots
  in one donated jitted ``lax.scan`` (``decode.build_span_fn``) — the
  host syncs once per span, not once per token.

Per-slot lengths are independent (never lockstep): a request admitted at
dispatch 40 decodes in the same device program as one admitted at
dispatch 0, each attending to exactly its own pages.

:func:`naive_generate` is the ``--engine naive`` baseline: the seed's
dense-cache serving loop, but with the batched single-dispatch prefill
and with request ``context`` actually threaded into the cache (the seed
dropped it, so audio/VLM decode ran unconditioned).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import decode as _decode
from repro.serving.paging import OutOfPages, PageAllocator, pages_needed

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request. ``arrival`` is the dispatch step at which
    the request becomes visible to the scheduler (0 = present at start),
    which is how tests inject late-joining requests deterministically."""

    rid: str
    tokens: tuple[int, ...]
    max_new: int
    arrival: int = 0

    def __post_init__(self):
        if len(self.tokens) < 1 or self.max_new < 1:
            raise ValueError("request needs >=1 prompt token and max_new >= 1")


@dataclasses.dataclass
class DecodeState:
    """Engine state between dispatches. ``cache`` lives on device (and is
    donated to every dispatch); everything else is host-side bookkeeping."""

    cache: PyTree
    tok: np.ndarray        # [B] int32 — each slot's pending (last sampled) token
    lengths: np.ndarray    # [B] int64 — tokens already written to each slot's pages
    owners: list[Request | None]

    @property
    def active(self) -> list[int]:
        return [i for i, o in enumerate(self.owners) if o is not None]


class Scheduler:
    """FIFO admission of pending requests into free batch slots.

    A request is admitted only when the pool can cover its *entire*
    worst-case footprint (prompt + max_new + one decode span, rounded up
    to pages) on top of the outstanding growth of already-admitted
    requests. Only the prompt pages are allocated up front; decode pages
    are allocated on demand — the accounting just guarantees that demand
    is always satisfiable.
    """

    def __init__(self, allocator: PageAllocator, requests: Sequence[Request],
                 span: int):
        self.alloc = allocator
        self.span = span
        self.pending = collections.deque(
            sorted(requests, key=lambda r: r.arrival))

    def _budget_pages(self, req: Request) -> int:
        return pages_needed(len(req.tokens) + req.max_new + self.span,
                            self.alloc.page_size)

    def _outstanding(self, owners: Sequence[Request | None]) -> int:
        """Pages in-flight requests may still allocate on demand."""
        tot = 0
        for r in owners:
            if r is not None:
                tot += max(0, self._budget_pages(r) - len(self.alloc.pages_for(r.rid)))
        return tot

    def admit(self, state: DecodeState, step: int) -> list[tuple[int, Request]]:
        """Fill free slots from the pending queue; allocates prompt pages."""
        admitted: list[tuple[int, Request]] = []
        for slot, owner in enumerate(state.owners):
            if owner is not None or not self.pending:
                continue
            req = self.pending[0]
            if req.arrival > step:
                break  # FIFO: don't let later arrivals jump the queue
            if self._budget_pages(req) > self.alloc.n_free - self._outstanding(state.owners):
                break
            self.pending.popleft()
            self.alloc.alloc(req.rid, pages_needed(len(req.tokens), self.alloc.page_size))
            state.owners[slot] = req
            admitted.append((slot, req))
        return admitted

    def finish(self, state: DecodeState, slot: int) -> int:
        """Release a finished request's pages and free its slot."""
        req = state.owners[slot]
        state.owners[slot] = None
        return self.alloc.release(req.rid)


class PagedEngine:
    """Paged-KV continuous-batching engine (``--engine paged``).

    ``run(requests)`` drives every request to completion and returns
    ``{rid: np.ndarray[max_new] generated tokens}``. Works for any model
    with ``supports_paged_decode`` (dense/moe attention families).
    """

    def __init__(self, model, params, *, slots: int = 4, page_size: int = 16,
                 max_pages: int = 64, decode_steps_per_dispatch: int = 8,
                 temperature: float = 0.0, attn_impl: str = "xla",
                 mesh=None, rng: jax.Array | None = None):
        if not model.supports_paged_decode:
            raise ValueError(
                f"arch_type {model.cfg.arch_type!r} has no paged decode path; "
                "serve it with --engine naive")
        self.model, self.params = model, params
        self.slots = slots
        self.page_size = page_size
        self.max_pages = max_pages
        self.span = decode_steps_per_dispatch
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        # mesh routing: the paged-decode Pallas kernel shard_maps its batch
        # slots over 'data' (KV pool replicated — page ids stay valid on
        # every device); None on single-device worlds
        self.mesh = mesh
        from repro.launch.sharding import kernel_specs

        kparts = kernel_specs(mesh, model.cfg) if mesh is not None else None
        self._prefill = _decode.build_prefill_fn(model, temperature,
                                                 kernel_parts=kparts)
        self._span_fn = _decode.build_span_fn(model, self.span, temperature,
                                              impl=attn_impl,
                                              kernel_parts=kparts)

    def _mesh_ctx(self):
        from contextlib import nullcontext

        return self.mesh if self.mesh is not None else nullcontext()

    def _init_state(self) -> DecodeState:
        return DecodeState(
            cache=self.model.init_paged_cache(self.max_pages, self.page_size),
            tok=np.zeros((self.slots,), np.int32),
            lengths=np.zeros((self.slots,), np.int64),
            owners=[None] * self.slots,
        )

    def run(self, requests: Sequence[Request]) -> dict[str, np.ndarray]:
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            raise ValueError("request ids must be unique")
        sched = Scheduler(PageAllocator(self.max_pages, self.page_size),
                          requests, self.span)
        # static page-table width for this run: the largest footprint any
        # single request can reach (compiled once per width)
        table_w = max(sched._budget_pages(r) for r in requests)
        state = self._init_state()
        emitted: dict[str, list[int]] = {r.rid: [] for r in requests}
        results: dict[str, np.ndarray] = {}
        step = 0

        def _maybe_finish(slot: int) -> None:
            req = state.owners[slot]
            if len(emitted[req.rid]) >= req.max_new:
                results[req.rid] = np.asarray(emitted[req.rid][: req.max_new],
                                              np.int32)
                sched.finish(state, slot)

        while sched.pending or state.active:
            admitted = sched.admit(state, step)
            if admitted:
                n = len(admitted)
                pmax = max(len(r.tokens) for _, r in admitted)
                toks = np.zeros((n, pmax), np.int32)
                lens = np.zeros((n,), np.int32)
                for i, (_, r) in enumerate(admitted):
                    toks[i, : len(r.tokens)] = r.tokens
                    lens[i] = len(r.tokens)
                rows = np.stack([sched.alloc.page_table_row(r.rid, table_w)
                                 for _, r in admitted])
                with self._mesh_ctx():
                    state.cache, first = self._prefill(
                        self.params, state.cache, toks, rows, lens,
                        jax.random.fold_in(self.rng, 2 * step))
                first = np.asarray(first)
                for i, (slot, r) in enumerate(admitted):
                    state.tok[slot] = first[i]
                    state.lengths[slot] = len(r.tokens)
                    emitted[r.rid].append(int(first[i]))
                    _maybe_finish(slot)

            active = state.active
            if active:
                for i in active:
                    sched.alloc.ensure(state.owners[i].rid,
                                       int(state.lengths[i]) + self.span)
                table = sched.alloc.page_table(
                    [o.rid if o is not None else None for o in state.owners],
                    table_w)
                with self._mesh_ctx():
                    state.cache, toks = self._span_fn(
                        self.params, state.cache, state.tok,
                        state.lengths.astype(np.int32), table,
                        jax.random.fold_in(self.rng, 2 * step + 1))
                toks = np.asarray(toks)  # [span, B]
                for i in active:
                    emitted[state.owners[i].rid].extend(toks[:, i].tolist())
                    state.lengths[i] += self.span
                    state.tok[i] = toks[-1, i]
                    _maybe_finish(i)
            elif sched.pending and not admitted:
                if sched.pending[0].arrival <= step:
                    raise OutOfPages(
                        f"request {sched.pending[0].rid!r} needs "
                        f"{sched._budget_pages(sched.pending[0])} pages but the "
                        f"pool has {sched.alloc.n_free} free even when idle — "
                        "raise --max-pages or lower --page-size waste")
            step += 1
        return results


# Model is a frozen dataclass over a hashable config, so jitted closures can
# be cached per model — repeated naive_generate calls (benchmarks, tests)
# reuse the compiled step instead of re-tracing under a fresh jax.jit wrapper.
@functools.lru_cache(maxsize=None)
def _jitted_decode_step(model):
    return jax.jit(model.decode_step)


@functools.lru_cache(maxsize=None)
def _jitted_prefill_with_cache(model):
    return jax.jit(model.prefill_with_cache)


def naive_generate(model, params, prompts: jax.Array, max_new: int,
                   temperature: float = 0.0, context: jax.Array | None = None,
                   rng: jax.Array | None = None,
                   batched_prefill: bool = True) -> jax.Array:
    """Dense-cache lockstep serving (``--engine naive``): the seed loop with
    two fixes — ``context`` is threaded into the cache via
    ``model.fill_context`` (the seed dropped it, leaving audio/VLM decode
    unconditioned), and attention-cache families prefill the whole prompt
    in one dispatch instead of stepping token by token.

    prompts [B, P] int32 -> tokens [B, P + max_new].
    """
    B, P = prompts.shape
    cache = model.init_cache(params, B, P + max_new)
    if context is not None:
        cache = model.fill_context(params, cache, context)
    step = _jitted_decode_step(model)

    def sample(logits, rng):
        if temperature > 0:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, logits / temperature, axis=-1)
            return tok.astype(jnp.int32), rng
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), rng

    out = [prompts[:, t] for t in range(P)]
    if batched_prefill and model.supports_batched_prefill:
        logits, cache = _jitted_prefill_with_cache(model)(params, cache, prompts)
        logits = logits[:, -1]
    else:
        # recurrent-state families: prefill by stepping the decode path
        for t in range(P):
            logits, cache = step(params, cache, prompts[:, t], jnp.int32(t))
    tok, rng = sample(logits, rng)
    out.append(tok)
    for t in range(P, P + max_new - 1):
        logits, cache = step(params, cache, tok, jnp.int32(t))
        tok, rng = sample(logits, rng)
        out.append(tok)
    return jnp.stack(out, axis=1)
