"""Jitted device programs for the serving engine.

Two dispatches cover the whole request lifecycle:

* :func:`build_prefill_fn` — ONE forward pass over an admitted group's
  (padded) prompts that writes the paged KV pool at every prompt position
  and samples each request's first token. This replaces the seed's
  token-by-token prefill loop with a single dispatch.
* :func:`build_span_fn` — ``lax.scan`` over N decode steps per dispatch
  (``--decode-steps-per-dispatch``), the decode-side analogue of the
  training superstep: the per-token host loop collapses to one donated
  jitted program that emits ``[span, B]`` tokens per call, so the host
  dispatches (and syncs) once per span instead of once per token.

Both donate the paged cache, so XLA updates the pool in place. On a mesh,
``kernel_parts`` (see :func:`repro.launch.sharding.kernel_specs`) is
installed around the traced bodies so the Pallas paged-decode kernel
shard_maps its batch slots over 'data' instead of failing to partition.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels.partition import kernel_partitioning


def sample_tokens(logits: jax.Array, rng: jax.Array, temperature: float) -> jax.Array:
    """Greedy (temperature 0) or temperature sampling. logits [B, V] -> [B]."""
    if temperature > 0:
        return jax.random.categorical(rng, logits / temperature, axis=-1).astype(jnp.int32)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def build_prefill_fn(model, temperature: float, kernel_parts=None) -> Callable:
    """jit: (params, cache, tokens [N,P], table [N,max_pages], lengths [N],
    rng) -> (cache, first_token [N]). Cache donated."""

    def prefill(params, cache, tokens, page_table, lengths, rng):
        with kernel_partitioning(kernel_parts):
            logits, cache = model.paged_prefill(params, cache, tokens,
                                                page_table, lengths)
        n = tokens.shape[0]
        last = logits[jnp.arange(n), lengths - 1]  # each row's true last position
        return cache, sample_tokens(last, rng, temperature)

    return jax.jit(prefill, donate_argnums=(1,))


def build_span_fn(model, span: int, temperature: float, impl: str = "xla",
                  kernel_parts=None) -> Callable:
    """jit: (params, cache, tok [B], lengths [B], table [B,max_pages], rng)
    -> (cache, tokens [span, B]). Cache donated.

    Step t consumes the carry token (written at its slot's current
    position), samples the next, and advances every slot's length; slots
    without a live request decode into the null page and their outputs are
    discarded by the host.
    """

    def span_fn(params, cache, tok, lengths, page_table, rng):
        def step(carry, step_rng):
            cache, tok, lens = carry
            logits, cache = model.paged_decode_step(params, cache, tok,
                                                    page_table, lens, impl=impl)
            nxt = sample_tokens(logits, step_rng, temperature)
            return (cache, nxt, lens + 1), nxt

        with kernel_partitioning(kernel_parts):
            (cache, _, _), toks = jax.lax.scan(
                step, (cache, tok, lengths), jax.random.split(rng, span))
        return cache, toks

    return jax.jit(span_fn, donate_argnums=(1,))
