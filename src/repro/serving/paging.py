"""Page-table allocator for the paged KV cache.

The device side is a fixed pool of fixed-size KV pages
(``models/attention.init_paged_cache``: ``[L, n_pages, page_size, KV,
hd]``). This module is the *host* side: which sequence owns which pages.
Allocator state never crosses to the device — each dispatch receives a
freshly built int32 page-table array, the same way the training kernels
receive their host-built visit schedules.

Invariants (pinned by tests/test_serving.py):

* page 0 is the reserved **null page** — never allocated, the scatter
  target for prompt padding and for slots decoding past their request
  (its contents are garbage by design and always masked);
* a page is owned by at most one sequence at a time (no double
  allocation);
* ``release`` returns every owned page to the free pool (release on
  finish), so a long-running server's pool never leaks;
* allocating beyond the pool raises :class:`OutOfPages` — the scheduler
  uses :meth:`PageAllocator.can_admit` to defer admission instead.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class OutOfPages(RuntimeError):
    """The fixed page pool cannot satisfy an allocation."""


def pages_needed(n_tokens: int, page_size: int) -> int:
    """Pages required to hold ``n_tokens`` KV entries."""
    return -(-n_tokens // page_size)


@dataclasses.dataclass
class PageAllocator:
    """Fixed pool of ``n_pages`` pages of ``page_size`` KV slots each.

    Page 0 is reserved (the null page), so ``n_pages - 1`` pages are
    usable. Per-sequence page lists are kept in allocation order ==
    position order: page ``i`` of a sequence holds positions
    ``[i*page_size, (i+1)*page_size)``.
    """

    n_pages: int
    page_size: int

    def __post_init__(self) -> None:
        if self.n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self._free: list[int] = list(range(self.n_pages - 1, 0, -1))
        self._owned: dict[object, list[int]] = {}

    # --- queries ---
    @property
    def n_free(self) -> int:
        return len(self._free)

    def pages_for(self, seq_id) -> list[int]:
        return list(self._owned.get(seq_id, ()))

    def capacity(self, seq_id) -> int:
        """Tokens the sequence's current pages can hold."""
        return len(self._owned.get(seq_id, ())) * self.page_size

    def can_admit(self, n_tokens: int) -> bool:
        return pages_needed(n_tokens, self.page_size) <= self.n_free

    # --- mutation ---
    def alloc(self, seq_id, n: int) -> list[int]:
        """Append ``n`` fresh pages to ``seq_id``'s page list."""
        if n > len(self._free):
            raise OutOfPages(
                f"need {n} pages, {len(self._free)} free "
                f"(pool {self.n_pages}, page 0 reserved)")
        pages = [self._free.pop() for _ in range(n)]
        self._owned.setdefault(seq_id, []).extend(pages)
        return pages

    def ensure(self, seq_id, n_tokens: int) -> list[int]:
        """Grow ``seq_id``'s allocation to cover ``n_tokens`` positions
        (allocate-on-demand during decode). Returns any new pages."""
        need = pages_needed(n_tokens, self.page_size) - len(self._owned.get(seq_id, ()))
        return self.alloc(seq_id, need) if need > 0 else []

    def release(self, seq_id) -> int:
        """Return every page owned by ``seq_id`` to the pool."""
        pages = self._owned.pop(seq_id, [])
        self._free.extend(reversed(pages))
        return len(pages)

    # --- device view ---
    def page_table_row(self, seq_id, max_pages: int) -> np.ndarray:
        """int32 [max_pages] page ids, 0-padded past the allocation."""
        pages = self._owned.get(seq_id, ())
        if len(pages) > max_pages:
            raise ValueError(
                f"sequence owns {len(pages)} pages > max_pages={max_pages}")
        row = np.zeros((max_pages,), np.int32)
        row[: len(pages)] = pages
        return row

    def page_table(self, seq_ids, max_pages: int) -> np.ndarray:
        """int32 [len(seq_ids), max_pages] table; ``None`` entries (empty
        slots) become all-null rows."""
        rows = [np.zeros((max_pages,), np.int32) if sid is None
                else self.page_table_row(sid, max_pages) for sid in seq_ids]
        return np.stack(rows) if rows else np.zeros((0, max_pages), np.int32)
