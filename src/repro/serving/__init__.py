"""Paged-KV continuous-batching serving engine (docs/architecture.md:
"Serving engine").

    from repro.serving import PagedEngine, Request, naive_generate
"""
from repro.serving.engine import (DecodeState, PagedEngine, Request,
                                  Scheduler, naive_generate)
from repro.serving.paging import OutOfPages, PageAllocator, pages_needed

__all__ = [
    "DecodeState", "OutOfPages", "PageAllocator", "PagedEngine", "Request",
    "Scheduler", "naive_generate", "pages_needed",
]
