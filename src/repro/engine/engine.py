"""TrainEngine: one donated, fully-jitted multi-round executor for every path.

The paper's hot loop — H inner steps + the outer sync — used to be re-wired
by hand in four places (launch/train.py, launch/dryrun.py, benchmarks,
examples), each with its own jit boundary, no buffer donation, and host
round-trips for metrics. The engine collapses them to a single builder:

  * ``TrainEngine(model, dcfg, icfg)`` compiles **one** jitted executor:
    ``lax.scan`` over the H inner steps, the outer sync — the declared
    pseudogradient transform chain of :func:`repro.core.diloco.make_outer`
    (Δ -> compress/EF -> reduce -> outer descent), plus the J streaming
    segment syncs — folded inside, and (via
    :mod:`repro.engine.superstep`) an outer ``lax.scan`` running R whole
    communication rounds per dispatch. The TrainState argument is
    **donated**, so rounds update in place instead of double-buffering the
    4 parameter-sized state copies;
  * on the production mesh the same builder threads the StepPlan shardings
    (worker axis -> 'pod', FSDP/TP within a pod) and activation rules through
    ``jax.jit``, so the CPU path and the 512-chip path lower from the same
    code;
  * the DP baseline is the degenerate config ``dp_config(inner)`` (K=1, H=1,
    no outer), and the single-round ``engine.step`` is the degenerate R=1
    case of the same superstep builder: DP AdamW / DP Muon, DiLoCo/MuLoCo,
    and single- vs multi-round dispatch all share one executor;
  * dispatch is asynchronous — metrics come back as device buffers
    (``[R, H]`` losses, ``[R]`` eval losses), and
    :mod:`repro.engine.driver` drains them on the host once per superstep
    while the next superstep is already running.
"""
from __future__ import annotations

from typing import Any, Callable

import jax

from repro.core.diloco import (
    DiLoCoConfig,
    diloco_init,
    diloco_round,
    dp_config,
    make_optimizer,
    make_outer,
)
from repro.engine.state import TrainState
from repro.engine.superstep import build_superstep_fn
from repro.models.api import Model
from repro.optim import OptimizerConfig

PyTree = Any


def build_round_fn(model: Model, dcfg: DiLoCoConfig, opt,
                   masks: list[PyTree] | None = None,
                   rules: dict | None = None,
                   spmd_axis: str | None = None,
                   outer=None, kernel_parts=None) -> Callable:
    """The un-jitted round callable shared by the engine and the dry-run
    StepPlans: H inner steps + sync(s) in one traceable program, with the
    activation-sharding rules (if any) and the kernel shard_map routing
    (``kernel_parts``, see :func:`repro.launch.sharding.kernel_specs`)
    installed around the whole round — both are trace-time contexts, so one
    installation covers every inner step, the wire stages, and the outer
    sync. ``outer`` is the declared pseudogradient chain (built from
    ``dcfg`` when omitted)."""

    def round_fn(state: PyTree, batches: PyTree) -> tuple[PyTree, dict]:
        from contextlib import nullcontext

        from repro.kernels.partition import kernel_partitioning
        from repro.models.common import activation_sharding

        act = activation_sharding(rules) if rules is not None else nullcontext()
        with act, kernel_partitioning(kernel_parts):
            return diloco_round(model, dcfg, opt, state, batches,
                                masks=masks, spmd_axis=spmd_axis, outer=outer)

    return round_fn


class TrainEngine:
    """Compiles and executes DiLoCo/MuLoCo (or DP) rounds.

    Usage::

        engine = TrainEngine(model, dcfg, icfg)
        state = engine.init(jax.random.PRNGKey(0))
        for r in range(rounds):
            state, info = engine.step(state, batches_for_round(stream, r, H))

        # or R rounds in ONE dispatch (leaves [R, H, K, B, ...]):
        state, out = engine.superstep(state, batches_for_span(stream, 0, H, R))

    ``step``/``superstep`` donate the incoming state; never reuse a state you
    passed in. For overlapping dispatch with host-side metrics draining use
    :func:`repro.engine.driver.run_rounds`.
    """

    def __init__(self, model: Model, dcfg: DiLoCoConfig, icfg: OptimizerConfig,
                 *, mesh=None, donate: bool = True,
                 rules: dict | None = None, spmd_axis: str | None = None,
                 kernel_parts=None):
        self.model = model
        self.dcfg = dcfg
        self.icfg = icfg
        self.opt = make_optimizer(dcfg, icfg)
        self.outer = make_outer(dcfg, state_dtype=icfg.state_dtype)
        self.mesh = mesh
        self.donate = donate
        self._rules = rules
        self._spmd_axis = spmd_axis
        if kernel_parts is None and mesh is not None:
            # default routing: shard_map the Pallas call sites on the
            # engine's mesh (None on single-device worlds)
            from repro.launch.sharding import kernel_specs

            kernel_parts = kernel_specs(mesh, getattr(model, "cfg", None))
        self.kernel_parts = kernel_parts
        self._masks = self._build_masks()
        self.round_fn = build_round_fn(model, dcfg, self.opt, masks=self._masks,
                                       rules=rules, spmd_axis=spmd_axis,
                                       outer=self.outer,
                                       kernel_parts=kernel_parts)
        # ONE eval closure serves both the in-superstep folded eval and the
        # standalone eval_loss jit — they must stay bitwise-identical (the
        # kernel routing context applies here too: folded eval runs outside
        # round_fn's context, and an un-shard_mapped pallas call would fail
        # to lower on the mesh)
        from repro.kernels.partition import kernel_partitioning

        def eval_loss_fn(params, batch):
            with kernel_partitioning(self.kernel_parts):
                return model.loss(params, batch)[0]
        # In-program checkpoint plumbing: the superstep's io_callback lands
        # in _emit_checkpoint, which forwards to whatever sink the driver
        # installed for the current run (checkpoint_sink is host-side mutable
        # state read at EXECUTION time, so one compiled trace serves every
        # run regardless of where its checkpoints go).
        self.checkpoint_sink: Callable | None = None
        self.superstep_fn = build_superstep_fn(self.round_fn,
                                               eval_loss_fn=eval_loss_fn,
                                               checkpoint_cb=self._emit_checkpoint)
        self._jitted: Callable | None = None
        self._eval_loss = jax.jit(eval_loss_fn)
        # driver telemetry: every superstep/step dispatch increments this —
        # the single-dispatch acceptance test (and the CI smoke) pins it
        self.dispatch_count = 0

    def _emit_checkpoint(self, state_dev: PyTree) -> None:
        """Host side of the in-program checkpoint io_callback.

        Receives the scan carry as a same-structure TrainState whose leaves
        are device arrays (bit-identical to what ``jax.device_get`` of the
        live state would return at that round — the callback reads the
        carry, it never re-computes anything). The sink MUST NOT block on a
        host transfer (``np.asarray`` / ``device_get``): this runs on the
        XLA callback thread while the dispatch that fired it is still
        executing, and on the CPU backend that transfer is serviced by the
        very thread parked inside the callback custom call — it deadlocks.
        Sinks stash the arrays and let the driver convert them from the
        main thread once the dispatch has drained."""
        sink = self.checkpoint_sink
        if sink is not None:
            sink(state_dev)

    # -- construction helpers ----------------------------------------------

    def _build_masks(self) -> list[PyTree] | None:
        if self.dcfg.streaming_partitions <= 1:
            return None
        params_abs = jax.eval_shape(
            lambda: self.model.init(jax.random.PRNGKey(0)))
        from repro.core.streaming import streaming_masks

        return streaming_masks(params_abs, self.dcfg.streaming_partitions)

    def abstract_state(self) -> TrainState:
        """ShapeDtypeStruct TrainState (nothing allocated)."""
        return jax.eval_shape(
            lambda: diloco_init(self.model, self.dcfg, self.icfg,
                                jax.random.PRNGKey(0)))

    def state_shardings(self, tensor_parallel: bool = True) -> TrainState:
        """StepPlan-compatible shardings for the TrainState on ``mesh``."""
        if self.mesh is None:
            raise ValueError("engine was built without a mesh")
        from repro.launch.sharding import diloco_state_shardings

        return diloco_state_shardings(self.mesh, self.abstract_state(),
                                      tensor_parallel=tensor_parallel)

    def place_state(self, state: TrainState, tensor_parallel: bool = True) -> TrainState:
        """Commit a TrainState to the mesh under the StepPlan shardings."""
        return jax.device_put(state, self.state_shardings(tensor_parallel))

    def place_batches(self, batches: PyTree, leading_scan: int = 1) -> PyTree:
        """Commit [H, K, B, ...] round batches (K->'pod', B->'data').

        ``leading_scan`` counts the unsharded scanned axes: 1 for a round's
        [H, ...] batches, 2 for a superstep's [R, H, ...] batches."""
        if self.mesh is None:
            return batches
        from repro.launch.sharding import batch_shardings

        return jax.device_put(
            batches, batch_shardings(self.mesh, batches, k_stacked=True,
                                     leading_scan=leading_scan))

    @property
    def jitted_round(self) -> Callable:
        """THE donated, jitted executor (compiled lazily).

        One jit object serves every dispatch width: each distinct
        (R, with/without eval) signature traces the same superstep builder
        once; R == 1 without eval *is* the single-round program."""
        if self._jitted is None:
            kw: dict = {}
            if self.donate:
                kw["donate_argnums"] = (0,)
            self._jitted = jax.jit(self.superstep_fn, **kw)
        return self._jitted

    # -- execution ----------------------------------------------------------

    def init(self, rng: jax.Array) -> TrainState:
        return diloco_init(self.model, self.dcfg, self.icfg, rng)

    def step(self, state: TrainState, batches: PyTree,
             participation: PyTree | None = None) -> tuple[TrainState, dict]:
        """One communication round; async dispatch, donated state.

        The degenerate R=1 dispatch of :meth:`superstep` — same executor,
        single-round metrics (``loss`` [H] plus the round's ``psi``). On a
        mesh, the committed shardings of ``state`` (see :meth:`place_state`)
        and the batches propagate through jit, so the round lowers with the
        production layout. ``participation`` is the round's [K] elastic
        worker mask (elastic configs only)."""
        state, out = self.superstep(
            state, jax.tree.map(lambda b: b[None], batches),
            participation=(None if participation is None
                           else jax.tree.map(lambda p: p[None], participation)))
        info = {k: (v if k == "psi" else v[0]) for k, v in out.items()}
        return state, info

    def superstep(self, state: TrainState, batches: PyTree,
                  eval_batches: PyTree | None = None,
                  participation: PyTree | None = None,
                  ckpt_flags: PyTree | None = None) -> tuple[TrainState, dict]:
        """R communication rounds in ONE dispatch; donated state.

        ``batches`` leaves are round-stacked [R, H, K, B, ...]. Returns
        ``(state, {"loss": f32[R, H]})`` plus ``"eval_loss": f32[R]`` when
        ``eval_batches`` (leaves [R, B, ...]) are supplied — the post-sync
        outer params of every round are evaluated inside the same program.
        ``participation`` ([R, K] float32 {0,1}, elastic configs only)
        supplies each round's worker mask; the scan threads row r into the
        state carry before round r runs. ``ckpt_flags`` ([R] bool) marks the
        rounds whose post-round state is emitted to the host through the
        in-program io_callback (install :attr:`checkpoint_sink` first) —
        this is what lets a whole run with a checkpoint cadence execute as
        one dispatch.
        """
        import jax.numpy as jnp

        self.dispatch_count += 1
        if participation is not None:
            participation = jnp.asarray(participation, jnp.float32)
        if ckpt_flags is not None:
            ckpt_flags = jnp.asarray(ckpt_flags, bool)
        if self.mesh is not None:
            from repro.launch.sharding import batch_shardings

            with self.mesh:
                if eval_batches is not None:
                    eval_batches = jax.device_put(
                        eval_batches, batch_shardings(
                            self.mesh, eval_batches, k_stacked=False,
                            leading_scan=1))
                return self.jitted_round(
                    state, self.place_batches(batches, leading_scan=2),
                    eval_batches, participation, ckpt_flags)
        return self.jitted_round(state, batches, eval_batches, participation,
                                 ckpt_flags)

    def eval_loss(self, params: PyTree, batch: PyTree) -> jax.Array:
        """Loss of the synced (outer) params on one un-stacked batch."""
        return self._eval_loss(params, batch)

    # -- introspection (used by the no-retrace / donation tests) ------------

    def lower(self, state: TrainState, batches: PyTree):
        """Lower the degenerate R=1 dispatch (the single-round program)."""
        return self.jitted_round.lower(
            state, jax.tree.map(lambda b: b[None], batches), None, None)


def dp_engine(model: Model, inner_name: str, icfg: OptimizerConfig,
              **kw) -> TrainEngine:
    """The data-parallel baseline as the degenerate engine config."""
    return TrainEngine(model, dp_config(inner_name), icfg, **kw)
