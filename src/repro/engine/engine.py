"""TrainEngine: one donated, fully-jitted round executor for every path.

The paper's hot loop — H inner steps + the outer sync — used to be re-wired
by hand in four places (launch/train.py, launch/dryrun.py, benchmarks,
examples), each with its own jit boundary, no buffer donation, and host
round-trips for metrics. The engine collapses them to a single builder:

  * ``TrainEngine(model, dcfg, icfg)`` compiles **one** jitted round function
    (``lax.scan`` over the H inner steps with the outer sync — and the J
    streaming segment syncs — folded inside) with the TrainState argument
    **donated**, so the round updates in place instead of double-buffering
    the 4 parameter-sized state copies;
  * on the production mesh the same builder threads the StepPlan shardings
    (worker axis -> 'pod', FSDP/TP within a pod) and activation rules through
    ``jax.jit``, so the CPU path and the 512-chip path lower from the same
    code;
  * the DP baseline is the degenerate config ``dp_config(inner)`` (K=1, H=1,
    no outer): DP AdamW / DP Muon and DiLoCo/MuLoCo share one executor;
  * ``engine.step`` dispatches asynchronously — metrics come back as device
    values, and :mod:`repro.engine.driver` drains them on the host while the
    next round is already running.
"""
from __future__ import annotations

from typing import Any, Callable

import jax

from repro.core.diloco import (
    DiLoCoConfig,
    diloco_init,
    diloco_round,
    dp_config,
    make_optimizer,
    make_outer,
)
from repro.engine.state import TrainState
from repro.models.api import Model
from repro.optim import OptimizerConfig

PyTree = Any


def build_round_fn(model: Model, dcfg: DiLoCoConfig, opt,
                   masks: list[PyTree] | None = None,
                   rules: dict | None = None,
                   spmd_axis: str | None = None,
                   outer=None) -> Callable:
    """The un-jitted round callable shared by the engine and the dry-run
    StepPlans: H inner steps + sync(s) in one traceable program, with the
    activation-sharding rules (if any) installed around the whole round.
    ``outer`` is the declared pseudogradient chain (built from ``dcfg`` when
    omitted)."""

    def round_fn(state: PyTree, batches: PyTree) -> tuple[PyTree, dict]:
        if rules is not None:
            from repro.models.common import activation_sharding

            with activation_sharding(rules):
                return diloco_round(model, dcfg, opt, state, batches,
                                    masks=masks, spmd_axis=spmd_axis, outer=outer)
        return diloco_round(model, dcfg, opt, state, batches,
                            masks=masks, spmd_axis=spmd_axis, outer=outer)

    return round_fn


class TrainEngine:
    """Compiles and executes DiLoCo/MuLoCo (or DP) rounds.

    Usage::

        engine = TrainEngine(model, dcfg, icfg)
        state = engine.init(jax.random.PRNGKey(0))
        for r in range(rounds):
            state, info = engine.step(state, batches_for_round(stream, r, H))

    ``step`` donates the incoming state; never reuse a state you passed in.
    For overlapping dispatch with host-side metrics draining use
    :func:`repro.engine.driver.run_rounds`.
    """

    def __init__(self, model: Model, dcfg: DiLoCoConfig, icfg: OptimizerConfig,
                 *, mesh=None, donate: bool = True,
                 rules: dict | None = None, spmd_axis: str | None = None):
        self.model = model
        self.dcfg = dcfg
        self.icfg = icfg
        self.opt = make_optimizer(dcfg, icfg)
        self.outer = make_outer(dcfg, state_dtype=icfg.state_dtype)
        self.mesh = mesh
        self.donate = donate
        self._rules = rules
        self._spmd_axis = spmd_axis
        self._masks = self._build_masks()
        self.round_fn = build_round_fn(model, dcfg, self.opt, masks=self._masks,
                                       rules=rules, spmd_axis=spmd_axis,
                                       outer=self.outer)
        self._jitted: Callable | None = None
        self._eval_loss = jax.jit(lambda params, batch: model.loss(params, batch)[0])

    # -- construction helpers ----------------------------------------------

    def _build_masks(self) -> list[PyTree] | None:
        if self.dcfg.streaming_partitions <= 1:
            return None
        params_abs = jax.eval_shape(
            lambda: self.model.init(jax.random.PRNGKey(0)))
        from repro.core.streaming import streaming_masks

        return streaming_masks(params_abs, self.dcfg.streaming_partitions)

    def abstract_state(self) -> TrainState:
        """ShapeDtypeStruct TrainState (nothing allocated)."""
        return jax.eval_shape(
            lambda: diloco_init(self.model, self.dcfg, self.icfg,
                                jax.random.PRNGKey(0)))

    def state_shardings(self, tensor_parallel: bool = True) -> TrainState:
        """StepPlan-compatible shardings for the TrainState on ``mesh``."""
        if self.mesh is None:
            raise ValueError("engine was built without a mesh")
        from repro.launch.sharding import diloco_state_shardings

        return diloco_state_shardings(self.mesh, self.abstract_state(),
                                      tensor_parallel=tensor_parallel)

    def place_state(self, state: TrainState, tensor_parallel: bool = True) -> TrainState:
        """Commit a TrainState to the mesh under the StepPlan shardings."""
        return jax.device_put(state, self.state_shardings(tensor_parallel))

    def place_batches(self, batches: PyTree) -> PyTree:
        """Commit [H, K, B, ...] round batches (K->'pod', B->'data')."""
        if self.mesh is None:
            return batches
        from repro.launch.sharding import batch_shardings

        return jax.device_put(
            batches, batch_shardings(self.mesh, batches, k_stacked=True,
                                     leading_scan=True))

    @property
    def jitted_round(self) -> Callable:
        """The one donated, jitted round executor (compiled lazily)."""
        if self._jitted is None:
            kw: dict = {}
            if self.donate:
                kw["donate_argnums"] = (0,)
            self._jitted = jax.jit(self.round_fn, **kw)
        return self._jitted

    # -- execution ----------------------------------------------------------

    def init(self, rng: jax.Array) -> TrainState:
        return diloco_init(self.model, self.dcfg, self.icfg, rng)

    def step(self, state: TrainState, batches: PyTree) -> tuple[TrainState, dict]:
        """One communication round; async dispatch, donated state.

        On a mesh, the committed shardings of ``state`` (see
        :meth:`place_state`) and the batches propagate through jit, so the
        round lowers with the production layout."""
        if self.mesh is not None:
            with self.mesh:
                return self.jitted_round(state, self.place_batches(batches))
        return self.jitted_round(state, batches)

    def eval_loss(self, params: PyTree, batch: PyTree) -> jax.Array:
        """Loss of the synced (outer) params on one un-stacked batch."""
        return self._eval_loss(params, batch)

    # -- introspection (used by the no-retrace / donation tests) ------------

    def lower(self, state: TrainState, batches: PyTree):
        return self.jitted_round.lower(state, batches)


def dp_engine(model: Model, inner_name: str, icfg: OptimizerConfig,
              **kw) -> TrainEngine:
    """The data-parallel baseline as the degenerate engine config."""
    return TrainEngine(model, dp_config(inner_name), icfg, **kw)
