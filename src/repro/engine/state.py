"""TrainState: the one state pytree every training path carries.

A registered-dataclass pytree replacing the raw ``dict`` state that
``core/diloco.py`` used to hand around. Fields mirror the paper's Algorithm 1:

  * ``outer_params`` / ``outer_opt`` — the synced parameters and the outer
    transform's state (``{"u": tree}`` for Nesterov, ``{}`` for plain SGD;
    no K axis; ZeRO-sharded over ('pod','data') on the production mesh);
  * ``worker_params`` / ``inner_state`` — K-stacked local replicas and their
    inner-optimizer transform-chain state (K sharded over 'pod');
  * ``ef`` — optional K-stacked error-feedback residuals: the state of the
    pseudogradient chain's EF stage (``None`` when the compression config
    doesn't use EF). It lives here rather than inside ``outer_opt`` because
    it shards with the workers (K -> 'pod'), not ZeRO over pods;
    :class:`repro.core.diloco.OuterOptimizer` packs both fields around its
    declared chain;
  * ``round`` — the on-device round counter. It lives in the state (not on
    the host) so that the superstep executor's scan-over-R carry advances it
    R times per dispatch and checkpoints taken at superstep boundaries
    resume at the true round index;
  * ``participation`` — optional [K] float32 {0,1} per-round worker mask
    (elastic DiLoCo: 0 = dropped this round). ``None`` on non-elastic
    configs, which keeps the legacy leaf set (old checkpoints load
    unchanged) and lets the round function emit the exact dense program;
  * ``pending`` — optional delayed-sync FIFO (``--sync-delay d``): leaves
    are ``[d, ...]``-stacked pseudogradients awaiting application. Round r
    computes Ψ_r (communication, EF, byte accounting all happen at r) but
    the outer descent applies ``pending[0]`` = Ψ_{r-d}; the FIFO shifts
    inside the superstep scan carry, so R>1 dispatch and donation survive;
  * ``health`` — optional health-sentinel running stats (``{"ema", "n"}``
    scalars, :mod:`repro.core.health`): the loss EMA the in-program spike
    detector compares against. Carried in the state so checkpoints capture
    it and a killed-and-resumed run replays identical spike decisions.
    ``None`` (no leaf, zero traced ops) when the sentinel is off.

Being a real pytree node, TrainState flows through ``jax.jit`` (with buffer
donation), ``jax.eval_shape``, checkpointing, and sharding-tree construction
unchanged — it is the carry of both engine scans (over the H inner steps
and over the R rounds of a superstep; per-round metrics travel separately
as the scan's stacked ``[R, ...]`` outputs, never through the carry). For backward compatibility with the dict era it also supports
mapping-style access (``state["outer_params"]``, ``state["round"]``), which
the analysis helpers and older tests use.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import jax

PyTree = Any

_FIELDS = ("outer_params", "outer_opt", "worker_params", "inner_state", "round",
           "ef", "participation", "pending", "health")


@dataclasses.dataclass
class TrainState:
    outer_params: PyTree
    outer_opt: PyTree
    worker_params: PyTree
    inner_state: PyTree
    round: jax.Array | Any
    ef: PyTree | None = None
    participation: jax.Array | None = None
    pending: PyTree | None = None
    health: PyTree | None = None

    # -- mapping-style compatibility with the pre-engine dict state ---------

    def __getitem__(self, key: str) -> PyTree:
        if key not in _FIELDS:
            raise KeyError(key)
        return getattr(self, key)

    def __setitem__(self, key: str, value: PyTree) -> None:
        if key not in _FIELDS:
            raise KeyError(key)
        setattr(self, key, value)

    def __contains__(self, key: str) -> bool:
        return key in _FIELDS and getattr(self, key) is not None

    def keys(self) -> Iterator[str]:
        return iter(k for k in _FIELDS if getattr(self, k) is not None)

    def items(self) -> Iterator[tuple[str, PyTree]]:
        return iter((k, getattr(self, k)) for k in _FIELDS if getattr(self, k) is not None)

    def get(self, key: str, default: PyTree = None) -> PyTree:
        v = getattr(self, key, None) if key in _FIELDS else None
        return default if v is None else v

    def replace(self, **kw) -> "TrainState":
        return dataclasses.replace(self, **kw)

    def map_groups(self, fn) -> "TrainState":
        """Build a parallel TrainState by applying ``fn(field_name, subtree)``
        to each non-None field (used for sharding-tree construction)."""
        return TrainState(**{
            f.name: (None if getattr(self, f.name) is None
                     else fn(f.name, getattr(self, f.name)))
            for f in dataclasses.fields(self)
        })


jax.tree_util.register_dataclass(
    TrainState,
    data_fields=list(_FIELDS),
    meta_fields=[],
)
