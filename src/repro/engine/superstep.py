"""Superstep executor: R communication rounds per device dispatch.

The engine's round program already folds the H inner steps and the outer
sync into one jitted function, but the host still re-enters the device once
per round — dispatch latency, donation bookkeeping, and metric reads that
are pure overhead on the paper's long runs (DiLoCo explicitly targets
low-*orchestration* training, and K=16 MuLoCo at 15B spends thousands of
rounds). The superstep retires that last per-round host round-trip:

  * :func:`build_superstep_fn` wraps THE round function (the same
    ``build_round_fn`` product the engine and the dry-run StepPlans compile)
    in a ``lax.scan`` over a *static* number of rounds R — batches arrive
    round-stacked ``[R, H, K, B, ...]`` and the scan slices one round per
    iteration;
  * per-round metrics accumulate into the scan's stacked outputs — a
    preallocated ``[R, H]`` loss buffer (plus an optional ``[R]`` eval-loss
    buffer) that the host drains ONCE per superstep
    (:func:`repro.engine.driver.run_rounds`), not once per round;
  * the round counter already lives in :class:`repro.engine.TrainState`, so
    it advances on device inside the scan carry and checkpoints/resume see
    the true round index;
  * eval rides inside the program: when ``eval_loss_fn`` is given and eval
    batches ``[R, B, ...]`` are passed, the loss of the freshly-synced outer
    params is computed after every round's sync, inside the same dispatch;
  * the single-round program is the **degenerate R=1 case**: at R == 1 the
    builder emits the round function directly (no scan), exactly mirroring
    how the DP baseline is the degenerate K=1/H=1 DiLoCo config. This is
    what keeps R a pure scheduling knob — every R that divides the run
    replays the identical arithmetic, bit for bit.

Eval cadence is handled by *choosing* R; checkpoint cadence no longer has
to be: with a checkpoint sink installed (``checkpoint_cb``) the scan body
emits the post-round state to the host through
``jax.experimental.io_callback`` on the rounds a boolean ``ckpt_flags``
mask selects, so the WHOLE run can be one donated dispatch regardless of
the checkpoint interval. Without flags the lowered program is literally
the pre-checkpoint program (the callback branch only enters the trace when
a mask is passed), which is what keeps the bit-parity pins intact.
:func:`effective_rounds_per_dispatch` still clamps a hand-chosen R to the
run's cadences — and resolves the ``"auto"`` request through a dispatch
cost model (measured host overhead vs device round time, whole-run when
unmeasured).
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax

PyTree = Any

# Fraction of a dispatch the host is allowed to cost before the cost model
# grows R ("auto" mode): R* is the smallest span divisor with
# host_overhead <= MAX_DISPATCH_OVERHEAD_FRAC * R * device_round_time.
MAX_DISPATCH_OVERHEAD_FRAC = 0.01


def build_superstep_fn(round_fn: Callable,
                       eval_loss_fn: Callable | None = None,
                       checkpoint_cb: Callable | None = None) -> Callable:
    """Wrap a round function into the R-rounds-per-dispatch executor.

    ``round_fn(state, round_batches) -> (state, {"loss": f32[H], "psi": ...})``
    is the product of :func:`repro.engine.build_round_fn`. The returned
    ``superstep_fn(state, batches, eval_batches=None)`` takes round-stacked
    batches (leaves ``[R, H, K, B, ...]``) and returns

    * ``state`` after R rounds (round counter advanced by R on device);
    * ``{"loss": f32[R, H], "comm_bytes": f32[R]}`` (``comm_bytes`` is each
      round's measured per-worker wire traffic, stacked like the losses) —
      and ``"eval_loss": f32[R]`` when ``eval_loss_fn`` was supplied and
      ``eval_batches`` (leaves ``[R, B, ...]``) are passed: the post-sync
      outer params of round i are evaluated inside the same program;
    * at R == 1 additionally ``"psi"``, the round's pseudogradient tree —
      the degenerate case *is* the single-round program (direct call, no
      scan), so its full metrics survive. For R > 1 psi is not stacked
      (R parameter-sized trees would dwarf the state).

    Elastic runs pass ``participation`` ([R, K] float32 {0,1} masks, one row
    per round): the scan threads row r into the carry's ``participation``
    field before round r runs, so the per-round mask travels through the
    same ``lax.scan`` xs as the batches and the carry structure never
    changes (the state must already carry a participation leaf — i.e. the
    config is elastic). The delayed-sync pending FIFO needs no handling
    here at all: it lives in the TrainState, so the scan carry shifts it
    round by round and R>1 dispatch + donation survive unchanged.

    In-program checkpoints: when the builder received a ``checkpoint_cb``
    host callable and the caller passes ``ckpt_flags`` (a ``[R]`` bool array,
    one per round), the post-round state of every flagged round is shipped to
    the host via an unordered ``jax.experimental.io_callback`` under a
    ``lax.cond`` — the device never leaves the program, the host sink
    receives the carry as a same-structure pytree of numpy leaves, and the
    round counter travels in the state so the sink knows which round it got.
    The emission branch reads the carry and computes nothing, so flagged and
    unflagged dispatches replay identical arithmetic; with ``ckpt_flags=None``
    (the default) the cond is not traced at all and the program is
    byte-for-byte the pre-checkpoint executor.

    R is read from the static leading batch dim at trace time; each distinct
    (R, with/without eval, with/without participation, with/without
    ckpt_flags) tuple is one trace of the same jitted executor.
    """

    def emit_checkpoint(flag, carry):
        from jax.experimental import io_callback

        def emit(c):
            io_callback(checkpoint_cb, None, c, ordered=False)
            return 0

        jax.lax.cond(flag, emit, lambda c: 0, carry)

    def superstep_fn(state: PyTree, batches: PyTree,
                     eval_batches: PyTree | None = None,
                     participation: PyTree | None = None,
                     ckpt_flags: PyTree | None = None) -> tuple[PyTree, dict]:
        R = jax.tree.leaves(batches)[0].shape[0]
        do_eval = eval_loss_fn is not None and eval_batches is not None
        do_ckpt = checkpoint_cb is not None and ckpt_flags is not None
        if participation is not None and state.get("participation") is None:
            raise ValueError(
                "per-round participation masks need an elastic TrainState "
                "(DiLoCoConfig(elastic=True)): the scan carry cannot gain "
                "a participation leaf the initial state lacks")
        if ckpt_flags is not None and checkpoint_cb is None:
            raise ValueError(
                "ckpt_flags passed but the superstep was built without a "
                "checkpoint_cb host sink (build_superstep_fn(checkpoint_cb=))")

        if R == 1:  # degenerate case: exactly the single-round program
            if participation is not None:
                state = state.replace(participation=participation[0])
            state, info = round_fn(state, jax.tree.map(lambda b: b[0], batches))
            out = {k: (v if k == "psi" else v[None]) for k, v in info.items()}
            if do_eval:
                out["eval_loss"] = eval_loss_fn(
                    state["outer_params"],
                    jax.tree.map(lambda e: e[0], eval_batches))[None]
            if do_ckpt:
                emit_checkpoint(ckpt_flags[0], state)
            return state, out

        def body(carry: PyTree, xs) -> tuple[PyTree, dict]:
            rb, eb, pr, cf = xs
            if pr is not None:
                carry = carry.replace(participation=pr)
            carry, info = round_fn(carry, rb)
            ys = {k: v for k, v in info.items() if k != "psi"}
            if do_eval:
                ys["eval_loss"] = eval_loss_fn(carry["outer_params"], eb)
            if cf is not None:
                emit_checkpoint(cf, carry)
            return carry, ys

        xs = (batches, eval_batches if do_eval else None, participation,
              ckpt_flags if do_ckpt else None)
        state, ys = jax.lax.scan(body, state, xs)
        return state, ys

    return superstep_fn


def auto_rounds_per_dispatch(rounds_to_run: int,
                             host_overhead_s: float | None = None,
                             device_round_s: float | None = None,
                             max_overhead_frac: float = MAX_DISPATCH_OVERHEAD_FRAC) -> int:
    """Cost-model choice of the superstep length R.

    Each dispatch costs a fixed host-side overhead (trace-cache lookup,
    donation bookkeeping, argument transfer, metric-buffer bookkeeping) that
    amortizes over the R device rounds it carries. The model picks the
    SMALLEST divisor of ``rounds_to_run`` whose per-dispatch overhead stays
    under ``max_overhead_frac`` of the device time it buys —
    ``host_overhead_s <= frac * R * device_round_s`` — because beyond that
    point larger R only grows host-side batch staging and metric latency.
    With no measurements (the driver cannot time a round it has not run) the
    model returns the whole span: maximal amortization, ONE dispatch for the
    run, the olmax whole-run-on-device regime.
    """
    if rounds_to_run <= 1:
        return max(1, rounds_to_run)
    if not host_overhead_s or not device_round_s:
        return rounds_to_run
    need = host_overhead_s / (max_overhead_frac * device_round_s)
    for r in range(1, rounds_to_run + 1):
        if rounds_to_run % r == 0 and r >= need:
            return r
    return rounds_to_run


def effective_rounds_per_dispatch(requested, rounds_to_run: int,
                                  checkpoint_every: int = 0,
                                  start: int = 0, *,
                                  host_overhead_s: float | None = None,
                                  device_round_s: float | None = None) -> int:
    """Clamp a requested superstep length to the run's cadences.

    The superstep must divide (a) the number of rounds left to run — the run
    is a whole number of equally-sized dispatches, so one trace serves all of
    them — and (b) when checkpointing is on, the checkpoint interval AND the
    ``start`` round of a resumed run, so every cadence boundary (absolute
    round count divisible by the interval) coincides with a superstep
    boundary ``start + k*R`` and state is on host exactly there. The clamp
    is the gcd of the requested R with each constraint — a common divisor,
    not necessarily the *largest* divisor <= requested (requesting R=4 on a
    6-round run yields 2, not 3; gcd keeps the rule deterministic and
    order-free). R = 1 recovers the classic one-dispatch-per-round driver.

    ``requested="auto"`` delegates the choice to the dispatch cost model
    (:func:`auto_rounds_per_dispatch`, fed the measured ``host_overhead_s``
    and ``device_round_s`` when the caller has them) before the same cadence
    clamps apply. Callers that fold checkpoints into the program
    (``ckpt_flags`` + the engine's checkpoint sink) pass
    ``checkpoint_every=0`` — the whole point of in-program emission is that
    R no longer needs to divide the checkpoint cadence.
    """
    if requested == "auto":
        r = auto_rounds_per_dispatch(rounds_to_run, host_overhead_s,
                                     device_round_s)
    else:
        r = max(1, int(requested))
    if rounds_to_run > 0:
        r = math.gcd(r, rounds_to_run)
    if checkpoint_every:
        r = math.gcd(r, checkpoint_every)
        if start:  # resumed off-cadence: boundaries must still hit it
            r = math.gcd(r, start)
    return max(1, r)
