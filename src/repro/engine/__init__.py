"""The training engine: one donated, fully-jitted multi-round executor.

``TrainState`` (registered pytree) + ``TrainEngine`` (compiles THE
superstep executor — R rounds per dispatch, single-round as the degenerate
R=1 case) + ``run_rounds`` (async driver draining metrics once per
superstep). All four training paths — launch/train, launch/dryrun,
benchmarks, examples — consume this subsystem instead of hand-wiring
diloco_init/diloco_round.
"""
from repro.engine.state import TrainState  # noqa: F401
from repro.engine.engine import (  # noqa: F401
    TrainEngine,
    build_round_fn,
    dp_engine,
)
from repro.engine.superstep import (  # noqa: F401
    auto_rounds_per_dispatch,
    build_superstep_fn,
    effective_rounds_per_dispatch,
)
from repro.engine.driver import run_rounds  # noqa: F401
from repro.engine.recovery import RecoveryPolicy, TrainingAborted  # noqa: F401
