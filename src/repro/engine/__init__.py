"""The training engine: one donated, fully-jitted round executor.

``TrainState`` (registered pytree) + ``TrainEngine`` (compiles THE round
function) + ``run_rounds`` (async multi-round driver). All four training
paths — launch/train, launch/dryrun, benchmarks, examples — consume this
subsystem instead of hand-wiring diloco_init/diloco_round.
"""
from repro.engine.state import TrainState  # noqa: F401
from repro.engine.engine import (  # noqa: F401
    TrainEngine,
    build_round_fn,
    dp_engine,
)
from repro.engine.driver import run_rounds  # noqa: F401
