"""Driver-side recovery: what to do when the health sentinel trips.

The in-program sentinel (:mod:`repro.core.health`) gets anomalies OUT of the
donated device program as a per-round ``[R]`` flag buffer; this module owns
what happens next, on the host, when :func:`repro.engine.driver.run_rounds`
drains a nonzero flag:

1. **rollback** — restore the last valid checkpoint (the policy's
   ``restore`` callable, typically
   :func:`repro.checkpoint.load_latest_valid` over the run's retention
   directory);
2. **skip** — advance the restored state's on-device round counter past the
   flagged round. Batches are a pure function of (seed, round), so bumping
   the counter is precisely "never feed that data span again": the retry
   cannot re-poison itself with the same batch;
3. **escalate** — rollbacks are budgeted (``max_rollbacks``); when the
   budget runs dry and a ``scale_lr`` rebuilder is provided, the inner LR is
   backed off (``lr_backoff``) and the budget refills, up to
   ``max_lr_halvings`` times; after that the run aborts with
   :class:`TrainingAborted` rather than looping forever on a divergent
   config.

The policy is deliberately host-side and engine-agnostic: the device program
never branches on health (bit-parity), and the driver's reaction is ordinary
Python — restore, bump a counter, keep dispatching.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

PyTree = Any


class TrainingAborted(RuntimeError):
    """Recovery escalation exhausted (or no valid checkpoint to roll back
    to): the run cannot make trustworthy progress and stops loudly."""


@dataclasses.dataclass
class RecoveryPolicy:
    """How ``run_rounds`` reacts to a drained health fault.

    ``restore()`` returns ``(state, checkpoint_round)`` — the freshest state
    the driver may trust — or ``None`` when nothing valid exists (which
    aborts: retrying from a poisoned state would be worse than stopping).
    ``scale_lr(scale)`` (optional) rebuilds the execution engine with the
    inner LR multiplied by ``scale`` and returns it (or ``None`` to keep the
    current engine); it is the escalation step between "skip the bad span"
    and "give up".
    """

    restore: Callable[[], tuple[PyTree, int] | None]
    max_rollbacks: int = 3
    scale_lr: Callable[[float], Any] | None = None
    lr_backoff: float = 0.5
    max_lr_halvings: int = 1
