"""Async superstep driver: overlap dispatch with host-side metrics drain.

JAX dispatch is asynchronous: ``engine.superstep`` returns device values
immediately while the rounds execute. The driver exploits that twice over:

* **R rounds per dispatch** — with ``rounds_per_dispatch=R`` the engine runs
  R whole communication rounds inside one ``lax.scan`` program
  (:mod:`repro.engine.superstep`), so the host touches the device once per
  superstep instead of once per round. R is auto-clamped
  (:func:`repro.engine.superstep.effective_rounds_per_dispatch`) to divide
  both the remaining rounds and the checkpoint cadence, which is how
  eval/checkpoint schedules survive multi-round dispatch without any
  in-program branching.
* **late metric reads** — up to ``max_in_flight`` dispatches' metrics stay
  un-materialized: the host converts a superstep's ``[R, H]`` loss buffer
  (and ``[R]`` eval-loss / measured ``comm_bytes`` buffers) to floats — a
  blocking device read — only
  after the next superstep has already been dispatched, so data generation +
  CSV writing + logging ride for free under the accelerator's compute. The
  seed-era loops blocked on ``float(info["loss"].mean())`` every round,
  serializing host and device.

Crash safety rides the same drain: when the config arms the health sentinel
(:mod:`repro.core.health`) the per-round ``[R]`` flag buffer is drained with
the other metrics, and a :class:`repro.engine.recovery.RecoveryPolicy` turns
a nonzero flag into rollback-to-last-valid-checkpoint + skip-the-bad-span +
bounded LR-backoff escalation — all host-side, so the device program never
branches on health. A ``should_stop`` probe (SIGTERM/SIGINT in
``launch/train.py``) lets a preempted run finish its in-flight dispatches,
drain every metric, and return a checkpointable state instead of dying
mid-span, and an ``inject`` hook (``core/faults.CrashPlan``) corrupts
chosen spans so every recovery path is provable end-to-end.
"""
from __future__ import annotations

import collections
from typing import Any, Callable

import jax
import numpy as np

from repro.engine.recovery import RecoveryPolicy, TrainingAborted
from repro.engine.superstep import effective_rounds_per_dispatch

PyTree = Any


class _Fault(Exception):
    """Internal: a drained health buffer carried a nonzero flag."""

    def __init__(self, round: int, code: int):
        super().__init__(f"health flag {code} at round {round}")
        self.round = round
        self.code = code


def _replace(state: PyTree, **kw) -> PyTree:
    if hasattr(state, "replace"):
        return state.replace(**kw)
    new = dict(state)
    new.update(kw)
    return new


def _with_round(state: PyTree, value: int) -> PyTree:
    """Set the on-device round counter, preserving dtype and placement."""
    old = state["round"]
    new = np.asarray(value, getattr(old, "dtype", np.int32))
    sharding = getattr(old, "sharding", None)
    if sharding is not None:
        new = jax.device_put(new, sharding)
    return _replace(state, round=new)


def run_rounds(engine, state, batches_for: Callable[[int], PyTree],
               rounds: int, *, start: int = 0,
               rounds_per_dispatch: int | str = 1,
               span_batches_for: Callable[[int, int], PyTree] | None = None,
               eval_batches_for: Callable[[int, int], PyTree] | None = None,
               eval_fn: Callable[[Any, int], jax.Array] | None = None,
               participation_for: Callable[[int, int], Any] | None = None,
               on_round: Callable[[dict], None] | None = None,
               on_state: Callable[[int, Any], None] | None = None,
               on_state_every: int = 1,
               checkpoint_in_program: bool = False,
               host_overhead_s: float | None = None,
               device_round_s: float | None = None,
               telemetry: dict | None = None,
               max_in_flight: int = 2,
               recovery: RecoveryPolicy | None = None,
               should_stop: Callable[[], bool] | None = None,
               inject: Callable[[int, int, PyTree, Any], tuple[PyTree, Any]] | None = None,
               ) -> tuple[Any, list[dict]]:
    """Run rounds ``start..rounds-1`` through the engine.

    ``batches_for(r)`` supplies the [H, K, B, ...] batches for round r; with
    ``rounds_per_dispatch > 1``, ``span_batches_for(r0, n)`` (when given)
    supplies the round-stacked [n, H, K, B, ...] leaves for rounds
    ``r0..r0+n-1`` in one call — otherwise the driver stacks ``batches_for``
    on host. ``eval_batches_for(r0, n)`` (optional) supplies [n, B, ...]
    eval batches; the engine then computes every round's post-sync eval loss
    *inside* the superstep program. ``eval_fn(state, r)`` is the legacy
    host-side alternative (a separately-jitted device scalar per round); it
    needs the state between rounds, so it pins the dispatch width to R=1.

    ``rounds_per_dispatch`` may be the string ``"auto"``: the dispatch cost
    model (:func:`repro.engine.superstep.auto_rounds_per_dispatch`, fed the
    measured ``host_overhead_s`` / ``device_round_s`` when supplied) picks R
    — whole-run single dispatch when unmeasured. Any resolved R replays the
    identical arithmetic bit for bit. The resolved R is re-clamped against
    the remaining span before every dispatch; on a fault-free run the clamp
    is the identity (R already divides everything), so the dispatch schedule
    is unchanged — it only bites when a rollback lands ``r0`` off-schedule.

    ``participation_for(r0, n)`` (elastic runs) supplies the [n, K] float32
    worker masks for rounds ``r0..r0+n-1``; the driver threads them into
    every dispatch and drains the per-round ``active_workers`` /
    ``staleness`` metric buffers into the records alongside the losses.

    ``on_round(metrics)`` fires per round when a superstep's metrics are
    drained to host floats. ``on_state(r, state)`` fires every
    ``on_state_every``-th round (r+1 divisible) with the new state, for
    checkpointing. By default the requested ``rounds_per_dispatch`` is
    clamped to divide that cadence, and all pending metrics are drained
    first so whatever on_round persisted (e.g. the CSV) never lags a saved
    checkpoint. With ``checkpoint_in_program=True`` the cadence clamp is
    dropped entirely: the driver passes per-round boolean ``ckpt_flags``
    into each superstep and installs a sink on the engine: the io_callback
    stashes each flagged round's carry (device arrays — converting on the
    callback thread deadlocks the CPU runtime against the running dispatch)
    and the driver replays the stash through ``on_state`` as numpy
    TrainStates once the producing dispatch has drained — R (and hence
    "auto" = the whole run) no longer needs to divide the checkpoint
    cadence. The carries are captured mid-dispatch but written after it
    completes, so a run killed mid-span keeps its previous checkpoint.

    Crash-safety hooks (all optional, all host-side):

    * ``recovery`` — a :class:`repro.engine.recovery.RecoveryPolicy`. When
      armed and a drained health buffer (the sentinel's per-round flags; see
      ``DiLoCoConfig.health``) is nonzero, the driver records NOTHING from
      the poisoned dispatch, drops every in-flight dispatch and stashed
      checkpoint carry, restores ``recovery.restore()``, advances the round
      counter to ``bad_round + 1`` (the seed-keyed data pipeline never
      replays the offending span), and keeps going — with bounded retries
      escalating through LR backoff to :class:`TrainingAborted`. Without a
      policy, nonzero flags are simply recorded (``health`` in the metrics).
    * ``should_stop`` — probed before each dispatch; when it returns True
      the driver stops dispatching, drains every in-flight superstep, and
      returns (``telemetry["preempted"]`` set) — the caller then writes its
      final checkpoint from a fully-drained state.
    * ``inject(r0, n, batches, state) -> (batches, state)`` — fault
      injection seam (``core/faults.CrashPlan.apply``): may corrupt the
      span-stacked batches or the state before the dispatch. Test/chaos
      only; None is a no-op.

    ``telemetry`` (optional dict) is filled with the resolved dispatch plan:
    ``rounds_per_dispatch``, ``dispatches`` (incremented as they happen),
    ``in_program_checkpoints`` — plus the recovery counters ``rollbacks``,
    ``skipped_rounds``, ``lr_scale``, and ``preempted``. Returns the final
    state and the per-round metrics.
    """
    span = rounds - start
    in_prog_ckpt = (checkpoint_in_program and on_state is not None
                    and bool(on_state_every) and eval_fn is None)
    cadence = on_state_every if (on_state is not None and not in_prog_ckpt) else 0
    R0 = effective_rounds_per_dispatch(
        rounds_per_dispatch if eval_fn is None else 1, span, cadence,
        start=start, host_overhead_s=host_overhead_s,
        device_round_s=device_round_s)

    pending: collections.deque = collections.deque()
    history: list[dict] = []
    H = engine.dcfg.sync_interval
    if telemetry is not None:
        telemetry.update(rounds_per_dispatch=R0, dispatches=0,
                         in_program_checkpoints=in_prog_ckpt,
                         rollbacks=0, skipped_rounds=0, lr_scale=1.0,
                         preempted=False)
    ckpt_stash: collections.deque = collections.deque()
    if in_prog_ckpt:
        # io_callback sink: the carry arrives as a device-leaf TrainState
        # with the round counter already advanced past the flagged round.
        # The sink only STASHES it — converting here (np.asarray/device_get
        # on the callback thread) deadlocks the CPU runtime against the
        # dispatch that fired the callback; flush_checkpoints converts on
        # the main thread once that dispatch has fully drained.
        def _sink(state_dev):
            ckpt_stash.append(state_dev)

        engine.checkpoint_sink = _sink

    def flush_checkpoints() -> None:
        while ckpt_stash:
            st = jax.tree.map(np.asarray, ckpt_stash.popleft())
            on_state(int(st["round"]) - 1, st)

    def drain_one() -> None:
        r0, n, loss, ev, cb, aw, st, hl = pending.popleft()
        hls = None if hl is None else np.atleast_1d(np.asarray(jax.device_get(hl)))
        if hls is not None and recovery is not None and np.any(hls != 0):
            # poisoned dispatch: record nothing from it — every round after
            # the flagged one trained on corrupted state, and CSV rows for
            # rounds the rollback is about to undo would be lies
            bad = int(np.argmax(hls != 0))
            raise _Fault(r0 + bad, int(hls[bad]))
        losses = np.atleast_2d(np.asarray(jax.device_get(loss)))  # [n, H]
        evs = None if ev is None else np.atleast_1d(np.asarray(jax.device_get(ev)))
        cbs = np.atleast_1d(np.asarray(jax.device_get(cb)))  # [n]
        aws = None if aw is None else np.atleast_1d(np.asarray(jax.device_get(aw)))
        sts = None if st is None else np.atleast_1d(np.asarray(jax.device_get(st)))
        for i in range(n):
            rec = {
                "round": r0 + i,
                "step": (r0 + i + 1) * H,
                "train_loss": float(losses[i].mean()),
                "train_loss_last": float(losses[i, -1]),
                "comm_bytes": float(cbs[i]),
            }
            if aws is not None:
                rec["active_workers"] = float(aws[i])
            if sts is not None:
                rec["staleness"] = float(sts[i])
            if evs is not None:
                rec["eval_loss"] = float(evs[i])
            if hls is not None:
                rec["health"] = float(hls[i])
            history.append(rec)
            if on_round is not None:
                on_round(rec)

    rollbacks_left = recovery.max_rollbacks if recovery is not None else 0
    lr_scale = 1.0
    lr_halvings = 0
    r0 = start
    done = False
    while not done:
        try:
            while r0 < rounds:
                if should_stop is not None and should_stop():
                    if telemetry is not None:
                        telemetry["preempted"] = True
                    break
                R = effective_rounds_per_dispatch(R0, rounds - r0, cadence,
                                                  start=r0)
                masks = (np.asarray(participation_for(r0, R), np.float32)
                         if participation_for is not None else None)
                if R == 1 and eval_batches_for is None and not in_prog_ckpt:
                    # classic path: single-round dispatch + optional host eval
                    b = batches_for(r0)
                    if inject is not None:
                        b1, state = inject(
                            r0, 1, jax.tree.map(lambda x: np.asarray(x)[None], b),
                            state)
                        b = jax.tree.map(lambda x: x[0], b1)
                    state, info = engine.step(
                        state, b,
                        participation=None if masks is None else masks[0])
                    ev = eval_fn(state, r0) if eval_fn is not None else None
                    loss, cb = info["loss"], info["comm_bytes"]
                    aw, st = info.get("active_workers"), info.get("staleness")
                    hl = info.get("health")
                else:
                    if span_batches_for is not None:
                        batches = span_batches_for(r0, R)
                    else:
                        batches = jax.tree.map(
                            lambda *bs: np.stack([np.asarray(b) for b in bs]),
                            *[batches_for(r0 + i) for i in range(R)])
                    if inject is not None:
                        batches, state = inject(r0, R, batches, state)
                    eb = (eval_batches_for(r0, R)
                          if eval_batches_for is not None else None)
                    flags = (np.asarray([(r0 + i + 1) % on_state_every == 0
                                         for i in range(R)], bool)
                             if in_prog_ckpt else None)
                    state, out = engine.superstep(state, batches, eb,
                                                  participation=masks,
                                                  ckpt_flags=flags)
                    ev = out.get("eval_loss")
                    loss, cb = out["loss"], out["comm_bytes"]
                    aw, st = out.get("active_workers"), out.get("staleness")
                    hl = out.get("health")
                if telemetry is not None:
                    telemetry["dispatches"] += 1
                # keep only the metric buffers alive; the rest (notably the
                # parameter-sized psi tree of the R=1 path) must be freeable
                # as soon as the dispatch's consumers drop it
                pending.append((r0, R, loss, ev, cb, aw, st, hl))
                if cadence and (r0 + R) % on_state_every == 0:
                    while pending:  # CSV must never lag a saved checkpoint
                        drain_one()
                    on_state(r0 + R - 1, state)
                while len(pending) > max_in_flight:
                    drain_one()
                if in_prog_ckpt and not pending:
                    # every dispatch issued so far has drained (drain_one
                    # blocks on its metric buffers), so the stashed carries
                    # are safely readable
                    flush_checkpoints()
                r0 += R
            while pending:
                drain_one()
            done = True
        except _Fault as fault:
            # Everything in flight descends from the poisoned state: drop
            # the metric buffers unread and the stashed checkpoint carries
            # unwritten (a poisoned carry must never become a "valid"
            # checkpoint on disk).
            pending.clear()
            ckpt_stash.clear()
            if rollbacks_left <= 0:
                if (recovery.scale_lr is not None
                        and lr_halvings < recovery.max_lr_halvings):
                    lr_halvings += 1
                    lr_scale *= recovery.lr_backoff
                    new_engine = recovery.scale_lr(lr_scale)
                    if new_engine is not None:
                        if in_prog_ckpt:
                            engine.checkpoint_sink = None
                            new_engine.checkpoint_sink = _sink
                        engine = new_engine
                    rollbacks_left = recovery.max_rollbacks
                    if telemetry is not None:
                        telemetry["lr_scale"] = lr_scale
                    print(f"recovery: rollback budget exhausted; inner LR "
                          f"backed off to x{lr_scale:g}")
                else:
                    raise TrainingAborted(
                        f"health flag {fault.code} at round {fault.round}: "
                        f"rollback and LR-backoff budgets exhausted") from None
            rollbacks_left -= 1
            restored = recovery.restore()
            if restored is None:
                raise TrainingAborted(
                    f"health flag {fault.code} at round {fault.round} but no "
                    f"valid checkpoint to roll back to") from None
            state, ckpt_round = restored
            skip_to = fault.round + 1
            state = _with_round(state, skip_to)
            if telemetry is not None:
                telemetry["rollbacks"] += 1
                telemetry["skipped_rounds"] += skip_to - ckpt_round
            print(f"recovery: round {fault.round} flagged (code {fault.code}); "
                  f"rolled back to checkpoint round {ckpt_round}, resuming at "
                  f"round {skip_to}")
            r0 = skip_to
    if in_prog_ckpt:
        # the sink belongs to THIS run; drop it so a later run without
        # in-program checkpoints can never fire a stale on_state
        jax.block_until_ready(jax.tree.leaves(state))
        flush_checkpoints()
        engine.checkpoint_sink = None
    return state, history
