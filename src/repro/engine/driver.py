"""Async multi-round driver: overlap dispatch with host-side metrics drain.

JAX dispatch is asynchronous: ``engine.step`` returns device values
immediately while the round executes. The driver exploits that by keeping up
to ``max_in_flight`` rounds' metrics un-materialized — the host converts
round r's losses to floats (a blocking device read) only after round r+1 has
already been dispatched, so data generation + CSV writing + logging ride for
free under the accelerator's compute. The seed-era loops blocked on
``float(info["loss"].mean())`` every round, serializing host and device.
"""
from __future__ import annotations

import collections
from typing import Any, Callable

import jax

PyTree = Any


def run_rounds(engine, state, batches_for: Callable[[int], PyTree],
               rounds: int, *, start: int = 0,
               eval_fn: Callable[[Any, int], jax.Array] | None = None,
               on_round: Callable[[dict], None] | None = None,
               on_state: Callable[[int, Any], None] | None = None,
               on_state_every: int = 1,
               max_in_flight: int = 2) -> tuple[Any, list[dict]]:
    """Run rounds ``start..rounds-1`` through the engine.

    ``batches_for(r)`` supplies the [H, K, B, ...] batches for round r.
    ``eval_fn(state, r)`` (optional) returns a device scalar evaluated after
    the round's sync (dispatched, not read). ``on_round(metrics)`` fires when
    a round's metrics are drained to host floats. ``on_state(r, state)``
    fires every ``on_state_every``-th round (r+1 divisible) with the new
    state, for checkpointing; all pending metrics are drained first so
    whatever on_round persisted (e.g. the CSV) never lags a saved
    checkpoint. Returns the final state and the per-round metrics.
    """
    pending: collections.deque = collections.deque()
    history: list[dict] = []

    def drain_one() -> None:
        r, loss, ev = pending.popleft()
        losses = jax.device_get(loss)
        rec = {
            "round": r,
            "step": (r + 1) * engine.dcfg.sync_interval,
            "train_loss": float(losses.mean()),
            "train_loss_last": float(losses[-1]),
        }
        if ev is not None:
            rec["eval_loss"] = float(jax.device_get(ev))
        history.append(rec)
        if on_round is not None:
            on_round(rec)

    for r in range(start, rounds):
        state, info = engine.step(state, batches_for(r))
        ev = eval_fn(state, r) if eval_fn is not None else None
        # keep only the loss vector alive; the rest of info (notably the
        # parameter-sized psi tree) must be freeable as soon as the round's
        # consumers drop it
        pending.append((r, info["loss"], ev))
        if on_state is not None and on_state_every and (r + 1) % on_state_every == 0:
            while pending:  # CSV/metrics must never lag a saved checkpoint
                drain_one()
            on_state(r, state)
        while len(pending) > max_in_flight:
            drain_one()
    while pending:
        drain_one()
    return state, history
