"""Async superstep driver: overlap dispatch with host-side metrics drain.

JAX dispatch is asynchronous: ``engine.superstep`` returns device values
immediately while the rounds execute. The driver exploits that twice over:

* **R rounds per dispatch** — with ``rounds_per_dispatch=R`` the engine runs
  R whole communication rounds inside one ``lax.scan`` program
  (:mod:`repro.engine.superstep`), so the host touches the device once per
  superstep instead of once per round. R is auto-clamped
  (:func:`repro.engine.superstep.effective_rounds_per_dispatch`) to divide
  both the remaining rounds and the checkpoint cadence, which is how
  eval/checkpoint schedules survive multi-round dispatch without any
  in-program branching.
* **late metric reads** — up to ``max_in_flight`` dispatches' metrics stay
  un-materialized: the host converts a superstep's ``[R, H]`` loss buffer
  (and ``[R]`` eval-loss / measured ``comm_bytes`` buffers) to floats — a
  blocking device read — only
  after the next superstep has already been dispatched, so data generation +
  CSV writing + logging ride for free under the accelerator's compute. The
  seed-era loops blocked on ``float(info["loss"].mean())`` every round,
  serializing host and device.
"""
from __future__ import annotations

import collections
from typing import Any, Callable

import jax
import numpy as np

from repro.engine.superstep import effective_rounds_per_dispatch

PyTree = Any


def run_rounds(engine, state, batches_for: Callable[[int], PyTree],
               rounds: int, *, start: int = 0,
               rounds_per_dispatch: int | str = 1,
               span_batches_for: Callable[[int, int], PyTree] | None = None,
               eval_batches_for: Callable[[int, int], PyTree] | None = None,
               eval_fn: Callable[[Any, int], jax.Array] | None = None,
               participation_for: Callable[[int, int], Any] | None = None,
               on_round: Callable[[dict], None] | None = None,
               on_state: Callable[[int, Any], None] | None = None,
               on_state_every: int = 1,
               checkpoint_in_program: bool = False,
               host_overhead_s: float | None = None,
               device_round_s: float | None = None,
               telemetry: dict | None = None,
               max_in_flight: int = 2) -> tuple[Any, list[dict]]:
    """Run rounds ``start..rounds-1`` through the engine.

    ``batches_for(r)`` supplies the [H, K, B, ...] batches for round r; with
    ``rounds_per_dispatch > 1``, ``span_batches_for(r0, n)`` (when given)
    supplies the round-stacked [n, H, K, B, ...] leaves for rounds
    ``r0..r0+n-1`` in one call — otherwise the driver stacks ``batches_for``
    on host. ``eval_batches_for(r0, n)`` (optional) supplies [n, B, ...]
    eval batches; the engine then computes every round's post-sync eval loss
    *inside* the superstep program. ``eval_fn(state, r)`` is the legacy
    host-side alternative (a separately-jitted device scalar per round); it
    needs the state between rounds, so it pins the dispatch width to R=1.

    ``rounds_per_dispatch`` may be the string ``"auto"``: the dispatch cost
    model (:func:`repro.engine.superstep.auto_rounds_per_dispatch`, fed the
    measured ``host_overhead_s`` / ``device_round_s`` when supplied) picks R
    — whole-run single dispatch when unmeasured. Any resolved R replays the
    identical arithmetic bit for bit.

    ``participation_for(r0, n)`` (elastic runs) supplies the [n, K] float32
    worker masks for rounds ``r0..r0+n-1``; the driver threads them into
    every dispatch and drains the per-round ``active_workers`` /
    ``staleness`` metric buffers into the records alongside the losses.

    ``on_round(metrics)`` fires per round when a superstep's metrics are
    drained to host floats. ``on_state(r, state)`` fires every
    ``on_state_every``-th round (r+1 divisible) with the new state, for
    checkpointing. By default the requested ``rounds_per_dispatch`` is
    clamped to divide that cadence, and all pending metrics are drained
    first so whatever on_round persisted (e.g. the CSV) never lags a saved
    checkpoint. With ``checkpoint_in_program=True`` the cadence clamp is
    dropped entirely: the driver passes per-round boolean ``ckpt_flags``
    into each superstep and installs a sink on the engine: the io_callback
    stashes each flagged round's carry (device arrays — converting on the
    callback thread deadlocks the CPU runtime against the running dispatch)
    and the driver replays the stash through ``on_state`` as numpy
    TrainStates once the producing dispatch has drained — R (and hence
    "auto" = the whole run) no longer needs to divide the checkpoint
    cadence. The carries are captured mid-dispatch but written after it
    completes, so a run killed mid-span keeps its previous checkpoint.

    ``telemetry`` (optional dict) is filled with the resolved dispatch plan:
    ``rounds_per_dispatch``, ``dispatches`` (incremented as they happen),
    ``in_program_checkpoints``. Returns the final state and the per-round
    metrics.
    """
    span = rounds - start
    in_prog_ckpt = (checkpoint_in_program and on_state is not None
                    and bool(on_state_every) and eval_fn is None)
    R = effective_rounds_per_dispatch(
        rounds_per_dispatch if eval_fn is None else 1, span,
        on_state_every if (on_state is not None and not in_prog_ckpt) else 0,
        start=start, host_overhead_s=host_overhead_s,
        device_round_s=device_round_s)

    pending: collections.deque = collections.deque()
    history: list[dict] = []
    H = engine.dcfg.sync_interval
    if telemetry is not None:
        telemetry.update(rounds_per_dispatch=R, dispatches=0,
                         in_program_checkpoints=in_prog_ckpt)
    ckpt_stash: collections.deque = collections.deque()
    if in_prog_ckpt:
        # io_callback sink: the carry arrives as a device-leaf TrainState
        # with the round counter already advanced past the flagged round.
        # The sink only STASHES it — converting here (np.asarray/device_get
        # on the callback thread) deadlocks the CPU runtime against the
        # dispatch that fired the callback; flush_checkpoints converts on
        # the main thread once that dispatch has fully drained.
        def _sink(state_dev):
            ckpt_stash.append(state_dev)

        engine.checkpoint_sink = _sink

    def flush_checkpoints() -> None:
        while ckpt_stash:
            st = jax.tree.map(np.asarray, ckpt_stash.popleft())
            on_state(int(st["round"]) - 1, st)

    def drain_one() -> None:
        r0, n, loss, ev, cb, aw, st = pending.popleft()
        losses = np.atleast_2d(np.asarray(jax.device_get(loss)))  # [n, H]
        evs = None if ev is None else np.atleast_1d(np.asarray(jax.device_get(ev)))
        cbs = np.atleast_1d(np.asarray(jax.device_get(cb)))  # [n]
        aws = None if aw is None else np.atleast_1d(np.asarray(jax.device_get(aw)))
        sts = None if st is None else np.atleast_1d(np.asarray(jax.device_get(st)))
        for i in range(n):
            rec = {
                "round": r0 + i,
                "step": (r0 + i + 1) * H,
                "train_loss": float(losses[i].mean()),
                "train_loss_last": float(losses[i, -1]),
                "comm_bytes": float(cbs[i]),
            }
            if aws is not None:
                rec["active_workers"] = float(aws[i])
            if sts is not None:
                rec["staleness"] = float(sts[i])
            if evs is not None:
                rec["eval_loss"] = float(evs[i])
            history.append(rec)
            if on_round is not None:
                on_round(rec)

    for r0 in range(start, rounds, R):
        masks = (np.asarray(participation_for(r0, R), np.float32)
                 if participation_for is not None else None)
        if R == 1 and eval_batches_for is None and not in_prog_ckpt:
            # classic path: single-round dispatch + optional host-side eval
            state, info = engine.step(
                state, batches_for(r0),
                participation=None if masks is None else masks[0])
            ev = eval_fn(state, r0) if eval_fn is not None else None
            loss, cb = info["loss"], info["comm_bytes"]
            aw, st = info.get("active_workers"), info.get("staleness")
        else:
            if span_batches_for is not None:
                batches = span_batches_for(r0, R)
            else:
                batches = jax.tree.map(
                    lambda *bs: np.stack([np.asarray(b) for b in bs]),
                    *[batches_for(r0 + i) for i in range(R)])
            eb = eval_batches_for(r0, R) if eval_batches_for is not None else None
            flags = (np.asarray([(r0 + i + 1) % on_state_every == 0
                                 for i in range(R)], bool)
                     if in_prog_ckpt else None)
            state, out = engine.superstep(state, batches, eb,
                                          participation=masks,
                                          ckpt_flags=flags)
            ev = out.get("eval_loss")
            loss, cb = out["loss"], out["comm_bytes"]
            aw, st = out.get("active_workers"), out.get("staleness")
        if telemetry is not None:
            telemetry["dispatches"] += 1
        # keep only the metric buffers alive; the rest (notably the
        # parameter-sized psi tree of the R=1 path) must be freeable as soon
        # as the dispatch's consumers drop it
        pending.append((r0, R, loss, ev, cb, aw, st))
        if (on_state is not None and on_state_every and not in_prog_ckpt
                and (r0 + R) % on_state_every == 0):
            while pending:  # CSV/metrics must never lag a saved checkpoint
                drain_one()
            on_state(r0 + R - 1, state)
        while len(pending) > max_in_flight:
            drain_one()
        if in_prog_ckpt and not pending:
            # every dispatch issued so far has drained (drain_one blocks on
            # its metric buffers), so the stashed carries are safely readable
            flush_checkpoints()
    while pending:
        drain_one()
    if in_prog_ckpt:
        # the sink belongs to THIS run; drop it so a later run without
        # in-program checkpoints can never fire a stale on_state
        jax.block_until_ready(jax.tree.leaves(state))
        flush_checkpoints()
        engine.checkpoint_sink = None
    return state, history
