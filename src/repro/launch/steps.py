"""Step builders: (jittable fn, abstract inputs, shardings) per input shape.

  train_4k     -> DiLoCo ``train_step`` (inner step, every-step cost),
                  ``sync_step`` (outer step, every-H cost — the cross-pod
                  collective the paper optimizes), ``round_step`` (the
                  engine's fused H-steps+sync round executor, donated), and
                  ``superstep`` (R rounds per dispatch — the scan-over-R
                  program production training actually runs; it threads the
                  round-step shardings with one extra unsharded scan axis)
  prefill_32k  -> ``prefill_step`` (full-seq forward, last-position logits)
  decode_32k / long_500k -> ``serve_step`` (1 token vs seq_len KV/SSM cache)

The train plans and :class:`repro.engine.TrainEngine` lower from the same
round builder (``repro.engine.build_round_fn``), so the production-mesh and
CPU paths compile the same program modulo shardings.

Everything is abstract (ShapeDtypeStruct via eval_shape): no parameter is
ever allocated, which is what lets 1T-param configs lower on the CPU host.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, config_for_shape
from repro.core.diloco import (
    DiLoCoConfig,
    diloco_init,
    inner_step,
    make_optimizer,
    make_outer,
    outer_step,
)
from repro.kernels.partition import kernel_partitioning
from repro.launch.sharding import (
    batch_shardings,
    cache_shardings,
    diloco_state_shardings,
    kernel_specs,
    params_shardings,
    replicated,
)
from repro.models.api import build_model
from repro.models.common import ModelConfig, activation_sharding
from repro.optim import OptimizerConfig
from repro.utils.tree import tree_count_params

PyTree = Any

# Configs above this many params lower with bf16 params + bf16 optimizer
# state (mixed-precision production policy; DESIGN.md §3).
BF16_PARAM_THRESHOLD = 3e10


@dataclasses.dataclass
class StepPlan:
    name: str
    fn: Callable
    args: tuple  # abstract ShapeDtypeStruct pytrees
    in_shardings: tuple
    donate: tuple[int, ...]
    meta: dict


def _needs_context(cfg: ModelConfig) -> bool:
    return cfg.arch_type in ("audio", "vlm")


def _context_struct(cfg: ModelConfig, lead: tuple[int, ...]) -> jax.ShapeDtypeStruct:
    n = cfg.n_audio_frames if cfg.arch_type == "audio" else cfg.n_image_tokens
    return jax.ShapeDtypeStruct((*lead, n, cfg.d_model), cfg.compute_dtype)


def production_model_config(cfg: ModelConfig, shape: str) -> ModelConfig:
    cfg = config_for_shape(cfg, shape)
    # best-known attention blocks from the committed autotune table first
    # (bitwise-gated at sweep time; a table miss or --no-autotune leaves the
    # ModelConfig constants), then pin the block sizes to divisors of the
    # plan's sequence length so every step plan (and the roofline's
    # visited-fraction term) sees the same static blocks the attention impls
    # will actually run with
    from repro.kernels.autotune import tuned_model_config
    from repro.kernels.flash_attention import clamp_block

    S = INPUT_SHAPES[shape].seq_len
    cfg = tuned_model_config(cfg, S)
    cfg = cfg.replace(attn_block_q=clamp_block(cfg.attn_block_q, S),
                      attn_block_kv=clamp_block(cfg.attn_block_kv, S))
    model = build_model(cfg)
    n = tree_count_params(jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))))
    if n > BF16_PARAM_THRESHOLD:
        cfg = cfg.replace(param_dtype="bfloat16")
    return cfg


def default_inner_cfg(cfg: ModelConfig) -> OptimizerConfig:
    state_dtype = "bfloat16" if cfg.param_dtype == "bfloat16" else "float32"
    return OptimizerConfig(lr=1.56e-2, weight_decay=5e-4, schedule="constant",
                           state_dtype=state_dtype)


def tp_friendly(cfg: ModelConfig, mesh: Mesh) -> bool:
    """Tensor parallelism only pays when heads split across the model axis.

    smollm (9 heads) and whisper (20 heads) can't split over model=16 — every
    attention op would reshard; they run sequence-parallel instead (§Perf
    iteration 3)."""
    model_n = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if cfg.arch_type == "ssm":
        return cfg.ssm_heads % model_n == 0
    return cfg.n_heads % model_n == 0 and cfg.hd % 2 == 0


def activation_rules(mesh: Mesh, batch_per_worker: int, cfg: ModelConfig,
                     train: bool = True) -> dict[str, P]:
    """Named activation sharding constraints installed around every step fn.

    The residual-stream carry of the layer scan is the dominant saved
    activation during training (one [B, S, d] per layer); sharding its d
    over 'model' cuts it 16x. MoE dispatch buffers keep d-passthrough
    sharding. 'ns_matrix'/'ns_out' reshard Muon momentum to layer-parallel
    whole matrices around Newton-Schulz (collective-free orthogonalization,
    §Perf iteration 2).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = "data" if batch_per_worker % sizes.get("data", 1) == 0 else None
    rules = {
        "residual": P(dp, None, "model"),
        "ffn_hidden": P(dp, None, "model"),
        "moe_tokens": P(dp, None, "model"),
        "moe_buffer": P(dp, None, "model"),
        "moe_dispatch": P(dp, None, None, "model"),
    }
    if not tp_friendly(cfg, mesh):
        # heads don't divide over the model axis: pin the per-head attention
        # activations replicated-over-model so the (unavoidable) gather
        # happens once per layer instead of inside every blockwise-attention
        # block step (§Perf iteration 3).
        rules["attn_kv"] = P(dp, None, None, None)
    # NOTE §Perf it. 2a/2b: layer-parallel Newton-Schulz resharding hints
    # ('ns_matrix') were tried and REFUTED — GSPMD lowers the layout change
    # via involuntary full rematerialization (peak 49 -> 1889 GiB/chip on
    # mistral-123b). The muon.step shard_hint hooks remain for future Shardy
    # backends; no rule is installed here.
    return rules


# ---------------------------------------------------------------------------
# Train plans
# ---------------------------------------------------------------------------


def build_train_plans(arch_cfg: ModelConfig, shape: str, mesh: Mesh,
                      dcfg: DiLoCoConfig | None = None,
                      rounds_per_dispatch: int = 4) -> list[StepPlan]:
    spec = INPUT_SHAPES[shape]
    assert spec.kind == "train"
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1)
    cfg = production_model_config(arch_cfg, shape)
    model = build_model(cfg)
    dcfg = dcfg or DiLoCoConfig(n_workers=n_pods, sync_interval=30, inner_name="muon")
    icfg = default_inner_cfg(cfg)
    if dcfg.inner_name == "muon_bp":
        # round-aligned block period: orthogonalize once per sync interval,
        # so the dry-run lowers the real periodic lax.cond/count program
        icfg = dataclasses.replace(icfg, ns_period=dcfg.sync_interval)
    opt = make_optimizer(dcfg, icfg)
    outer = make_outer(dcfg, state_dtype=icfg.state_dtype)

    state_abs = jax.eval_shape(lambda: diloco_init(model, dcfg, icfg, jax.random.PRNGKey(0)))
    K = dcfg.n_workers
    B = spec.global_batch // K
    S = spec.seq_len
    batch_abs = {
        "tokens": jax.ShapeDtypeStruct((K, B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((K, B, S), jnp.int32),
    }
    if _needs_context(cfg):
        batch_abs["context"] = _context_struct(cfg, (K, B))

    tp = tp_friendly(cfg, mesh)
    state_sh = diloco_state_shardings(mesh, state_abs, tensor_parallel=tp)
    batch_sh = batch_shardings(mesh, batch_abs, k_stacked=True)
    rules = activation_rules(mesh, B, cfg, train=True)
    n_pods_mesh = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 0)
    spmd_axis = "pod" if n_pods_mesh else None
    # ONE routing object per plan set: every Pallas call site below
    # (attention inside the inner step, NS in the optimizer, wire
    # quantize + fused outer update in the sync) shard_maps itself from it
    kparts = kernel_specs(mesh, cfg)

    # Every donated plan pins its OUTPUT state to the committed input layout.
    # Without the constraint GSPMD is free to propagate a different sharding
    # onto the returned TrainState (observed on the single-pod 16x16 mesh:
    # TP-unfriendly archs commit the outer state without a 'model' dim, but
    # propagation re-shards the outputs over 'model') — and an output whose
    # per-chip layout differs from the donated input cannot alias, silently
    # forfeiting the in-place update donation exists for.
    def pin_state(new_state):
        return jax.lax.with_sharding_constraint(new_state, state_sh)

    def train_step(state, batch):
        with activation_sharding(rules), kernel_partitioning(kparts):
            new_state, info = inner_step(model, opt, state, batch,
                                         spmd_axis=spmd_axis)
        return pin_state(new_state), info

    def sync_step(state):
        with kernel_partitioning(kparts):
            new_state, _psi = outer_step(dcfg, state, outer=outer)
        return pin_state(new_state)

    # the fused round executor — same builder the TrainEngine compiles
    from repro.engine import build_round_fn, build_superstep_fn

    round_fn0 = build_round_fn(model, dcfg, opt, masks=None, rules=rules,
                               spmd_axis=spmd_axis, outer=outer,
                               kernel_parts=kparts)

    def round_fn(state, batches):
        new_state, info = round_fn0(state, batches)
        return pin_state(new_state), info

    H = dcfg.sync_interval
    round_batch_abs = jax.tree.map(
        lambda b: jax.ShapeDtypeStruct((H, *b.shape), b.dtype), batch_abs)
    round_batch_sh = batch_shardings(mesh, round_batch_abs, k_stacked=True,
                                     leading_scan=1)

    # the superstep executor: scan-over-R of the same round function, with
    # the round-step shardings threaded under one extra unsharded scan axis
    R = max(1, rounds_per_dispatch)
    superstep_fn = build_superstep_fn(round_fn)
    super_batch_abs = jax.tree.map(
        lambda b: jax.ShapeDtypeStruct((R, *b.shape), b.dtype), round_batch_abs)
    super_batch_sh = batch_shardings(mesh, super_batch_abs, k_stacked=True,
                                     leading_scan=2)

    plans = [
        StepPlan(
            name="train_step",
            fn=train_step,
            args=(state_abs, batch_abs),
            in_shardings=(state_sh, batch_sh),
            donate=(0,),
            meta={"kind": "train", "tokens_per_step": spec.global_batch * S,
                  "amortize": 1, "cfg": cfg, "dcfg": dcfg},
        ),
        StepPlan(
            name="sync_step",
            fn=sync_step,
            args=(state_abs,),
            in_shardings=(state_sh,),
            donate=(0,),
            meta={"kind": "sync", "tokens_per_step": 0,
                  "amortize": dcfg.sync_interval, "cfg": cfg, "dcfg": dcfg},
        ),
        StepPlan(
            name="round_step",
            fn=round_fn,
            args=(state_abs, round_batch_abs),
            in_shardings=(state_sh, round_batch_sh),
            donate=(0,),
            meta={"kind": "round", "tokens_per_step": spec.global_batch * S * H,
                  "amortize": 1, "cfg": cfg, "dcfg": dcfg},
        ),
        StepPlan(
            name="superstep",
            fn=superstep_fn,
            args=(state_abs, super_batch_abs),
            in_shardings=(state_sh, super_batch_sh),
            donate=(0,),
            meta={"kind": "superstep",
                  "tokens_per_step": spec.global_batch * S * H * R,
                  "amortize": 1, "cfg": cfg, "dcfg": dcfg,
                  "rounds_per_dispatch": R},
        ),
    ]
    return plans


# ---------------------------------------------------------------------------
# Serve plans (prefill / decode)
# ---------------------------------------------------------------------------


def build_serve_plan(arch_cfg: ModelConfig, shape: str, mesh: Mesh) -> StepPlan:
    spec = INPUT_SHAPES[shape]
    cfg = production_model_config(arch_cfg, shape)
    model = build_model(cfg)
    params_abs = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    tp = tp_friendly(cfg, mesh)
    B = spec.global_batch
    # expert-parallel serving pays when there is a batch to amortize the
    # token all-to-all; at B=1 (long_500k) the FSDP layout wins (§Perf it.3).
    ep = bool(cfg.n_experts) and B >= 32
    params_sh = params_shardings(mesh, params_abs, tensor_parallel=tp,
                                 expert_parallel=ep)

    kparts = kernel_specs(mesh, cfg)
    if spec.kind == "prefill":
        tokens = jax.ShapeDtypeStruct((B, spec.seq_len), jnp.int32)
        args: tuple = (params_abs, tokens)
        shards: tuple = (params_sh, batch_shardings(mesh, tokens, k_stacked=False))
        rules = activation_rules(mesh, B, cfg, train=False)
        if _needs_context(cfg):
            ctx = _context_struct(cfg, (B,))
            args = args + (ctx,)
            shards = shards + (batch_shardings(mesh, ctx, k_stacked=False),)

            def prefill_step(params, tokens, context):
                with activation_sharding(rules), kernel_partitioning(kparts):
                    return model.prefill(params, tokens, context=context)
        else:

            def prefill_step(params, tokens):
                with activation_sharding(rules), kernel_partitioning(kparts):
                    return model.prefill(params, tokens)

        return StepPlan(
            name="prefill_step", fn=prefill_step, args=args, in_shardings=shards,
            donate=(),
            meta={"kind": "prefill", "tokens_per_step": B * spec.seq_len, "amortize": 1,
                  "cfg": cfg},
        )

    # decode: one token against a seq_len-deep cache
    cache_abs = jax.eval_shape(lambda: model.init_cache(params_abs, B, spec.seq_len))
    cache_sh = cache_shardings(mesh, cache_abs, batch=B)
    token = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    rules = activation_rules(mesh, B, cfg, train=False)
    if ep:
        # serving layout: expert-parallel weight banks; tiny token buffers
        # move to the experts (all-to-all) rather than the 100s-of-GB banks
        # gathering to the tokens (§Perf iteration 3, kimi decode -92%).
        rules["moe_dispatch"] = P(None, "model", None, None)
        rules["moe_buffer"] = P(None, None, "model")

    def serve_step(params, cache, token, pos):
        with activation_sharding(rules), kernel_partitioning(kparts):
            return model.decode_step(params, cache, token, pos)

    return StepPlan(
        name="serve_step", fn=serve_step,
        args=(params_abs, cache_abs, token, pos),
        in_shardings=(params_sh, cache_sh,
                      batch_shardings(mesh, token, k_stacked=False),
                      replicated(mesh, pos)),
        donate=(1,),
        meta={"kind": "decode", "tokens_per_step": B, "amortize": 1, "cfg": cfg},
    )


def build_plans(arch_cfg: ModelConfig, shape: str, mesh: Mesh, **kw) -> list[StepPlan]:
    if INPUT_SHAPES[shape].kind == "train":
        return build_train_plans(arch_cfg, shape, mesh, **kw)
    return [build_serve_plan(arch_cfg, shape, mesh)]
