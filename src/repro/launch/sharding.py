"""Sharding rules: map every pytree leaf to a PartitionSpec on the
production mesh.

Scheme (DESIGN.md §3):
  * worker-stacked trees (leading K): K -> 'pod';
  * weight matrices [..., m, n]: m -> 'data' (FSDP / ZeRO-3), n -> 'model'
    (tensor parallel); MoE expert banks [..., E, m, n]: E -> 'model'
    (expert parallel), m -> 'data';
  * outer/DiLoCo state (params, Nesterov u, EF residuals) has no K axis and
    is sharded over ('pod','data') x 'model' — ZeRO-sharding the *outer*
    optimizer over pods, which is what lets 100B+ configs hold 4 param
    copies;
  * KV caches / SSM states: batch -> 'data', longest remaining
    divisible axis (cache length / heads) -> 'model';
  * every rule falls back to replication when a dim is not divisible.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.utils.tree import tree_map_with_path

PyTree = Any


def _div(dim: int, n: int) -> bool:
    return n > 0 and dim % n == 0 and dim >= n


def _axis(mesh_sizes: dict[str, int], name: str, dim: int):
    return name if name in mesh_sizes and _div(dim, mesh_sizes[name]) else None


def param_spec(path: str, shape: tuple[int, ...], mesh_sizes: dict[str, int],
               outer: bool = False, tensor_parallel: bool = True,
               expert_parallel: bool = False) -> P:
    """Spec for one (non-K-stacked) parameter/optimizer-state leaf.

    ``outer=True`` additionally folds the 'pod' axis into the fsdp dim
    (outer-state ZeRO over pods). ``tensor_parallel=False`` drops the model
    axis from weights (TP-unfriendly archs: heads not divisible by the model
    axis — they use sequence parallelism instead). ``expert_parallel`` shards
    MoE banks E->model (serving layout: weights stay resident, tokens move).
    """
    nd = len(shape)
    fsdp: Any = ("pod", "data") if (outer and "pod" in mesh_sizes) else "data"
    fsdp_size = mesh_sizes.get("data", 1) * (mesh_sizes.get("pod", 1) if (outer and "pod" in mesh_sizes) else 1)

    def fsdp_axis(dim):
        return fsdp if _div(dim, fsdp_size) else ("data" if _div(dim, mesh_sizes.get("data", 0)) else None)

    if nd <= 1:
        return P(*([None] * nd))
    spec = [None] * nd
    if expert_parallel and nd >= 3 and ("experts" in path):
        spec[-3] = _axis(mesh_sizes, "model", shape[-3])
        spec[-2] = fsdp_axis(shape[-2])
        return P(*spec)
    # Matrices (incl. MoE expert banks [..., E, m, n]): trailing dims get
    # (fsdp, model); the expert dim stays unsharded so dispatch buffers with
    # d-passthrough sharding contract without resharding the weight bank.
    spec[-2] = fsdp_axis(shape[-2])
    if tensor_parallel:
        spec[-1] = _axis(mesh_sizes, "model", shape[-1])
    return P(*spec)


def worker_spec(path: str, shape: tuple[int, ...], mesh_sizes: dict[str, int],
                tensor_parallel: bool = True) -> P:
    """Spec for a K-stacked leaf: K -> 'pod', rest per param_spec."""
    inner = param_spec(path, shape[1:], mesh_sizes, outer=False,
                       tensor_parallel=tensor_parallel)
    pod = "pod" if ("pod" in mesh_sizes and _div(shape[0], mesh_sizes["pod"])) else None
    return P(pod, *inner)


def cache_spec(shape: tuple[int, ...], batch: int, mesh_sizes: dict[str, int]) -> P:
    """KV-cache / SSM-state leaf: batch -> 'data', longest other -> 'model'."""
    spec = [None] * len(shape)
    data_n = mesh_sizes.get("data", 0)
    model_n = mesh_sizes.get("model", 0)
    b_idx = None
    for i, d in enumerate(shape):
        if d == batch and _div(d, data_n):
            b_idx = i
            spec[i] = "data"
            break
    best, best_dim = None, 0
    for i, d in enumerate(shape):
        if i == b_idx or i == 0 and len(shape) > 3:
            # skip the layer-stack axis (leading, scanned) and the batch axis
            continue
        if _div(d, model_n) and d > best_dim:
            best, best_dim = i, d
    if best is not None:
        spec[best] = "model"
    return P(*spec)


# ---------------------------------------------------------------------------
# Tree-level builders
# ---------------------------------------------------------------------------


def params_shardings(mesh: Mesh, params: PyTree, outer: bool = False,
                     tensor_parallel: bool = True, expert_parallel: bool = False) -> PyTree:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return tree_map_with_path(
        lambda p, x: NamedSharding(mesh, param_spec(
            p, x.shape, sizes, outer=outer, tensor_parallel=tensor_parallel,
            expert_parallel=expert_parallel)), params
    )


def worker_shardings(mesh: Mesh, tree: PyTree, tensor_parallel: bool = True) -> PyTree:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return tree_map_with_path(
        lambda p, x: NamedSharding(mesh, worker_spec(
            p, x.shape, sizes, tensor_parallel=tensor_parallel)), tree
    )


def diloco_state_shardings(mesh: Mesh, state: PyTree, tensor_parallel: bool = True) -> PyTree:
    """Shardings for the full TrainState pytree (see diloco_init).

    Returns a pytree of NamedShardings with the same structure as ``state``
    (TrainState in, TrainState out), usable directly as jit in_shardings.
    """

    def for_group(key, sub):
        if key in ("worker_params", "inner_state", "ef"):
            return worker_shardings(mesh, sub, tensor_parallel=tensor_parallel)
        if key in ("outer_params", "outer_opt"):
            return params_shardings(mesh, sub, outer=True,
                                    tensor_parallel=tensor_parallel)
        if key == "pending":
            # delayed-sync FIFO: [d, ...]-stacked pseudogradients. The tiny
            # FIFO depth stays unsharded; the payload keeps the outer-state
            # ZeRO layout so the shift + descent never reshard.
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            return tree_map_with_path(
                lambda p, x: NamedSharding(mesh, P(None, *param_spec(
                    p, x.shape[1:], sizes, outer=True,
                    tensor_parallel=tensor_parallel))), sub)
        # counters + the [K] elastic participation mask: replicated
        return jax.tree.map(lambda x: NamedSharding(mesh, P()), sub)

    if hasattr(state, "map_groups"):  # TrainState
        return state.map_groups(for_group)
    return {key: for_group(key, sub) for key, sub in state.items()}


def batch_shardings(mesh: Mesh, batch: PyTree, k_stacked: bool = True,
                    leading_scan: int = 0) -> PyTree:
    """``leading_scan`` counts leading scanned axes left unsharded: 1 for
    [H, K, B, ...] round-stacked batches (the engine's fused round input),
    2 for the superstep's [R, H, K, B, ...]; K and B follow the per-step
    rule either way. (``True`` is accepted as 1 for the older bool form.)"""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_lead = int(leading_scan)

    def spec(path, x):
        nd = len(x.shape)
        shape = x.shape
        lead: tuple = ()
        if n_lead:
            lead, shape, nd = (None,) * n_lead, x.shape[n_lead:], nd - n_lead
        if k_stacked:
            pod = "pod" if ("pod" in sizes and _div(shape[0], sizes["pod"])) else None
            data = "data" if (nd > 1 and _div(shape[1], sizes.get("data", 0))) else None
            return NamedSharding(mesh, P(*lead, pod, data, *([None] * (nd - 2))))
        data = "data" if _div(shape[0], sizes.get("data", 0)) else None
        return NamedSharding(mesh, P(*lead, data, *([None] * (nd - 1))))

    return tree_map_with_path(spec, batch)


def cache_shardings(mesh: Mesh, cache: PyTree, batch: int) -> PyTree:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree.map(
        lambda x: NamedSharding(mesh, cache_spec(x.shape, batch, sizes)), cache
    )


def replicated(mesh: Mesh, tree: PyTree) -> PyTree:
    return jax.tree.map(lambda x: NamedSharding(mesh, P()), tree)


# ---------------------------------------------------------------------------
# Kernel partitioning (shard_map routing for the Pallas call sites)
# ---------------------------------------------------------------------------


def kernel_specs(mesh: Mesh | None, cfg=None):
    """The per-kernel shard_map routing for a plan's mesh — ONE place maps
    the plan-level layout (``diloco_state_shardings`` / ``batch_shardings``
    above) onto the block-local axes each kernel shards:

    * flash attention: the fused [B*KV, ...] batch-head axis over
      ('data', 'model') — B rides 'data' exactly like ``batch_shardings``
      puts it there, KV-heads ride 'model' like ``param_spec`` puts head
      projections there; the worker axis K arrives via
      ``vmap(spmd_axis_name='pod')`` on top. TP-unfriendly archs (heads
      don't divide the model axis — the same test ``tp_friendly`` applies
      to the activation rules) drop 'model' and shard batch only.
    * wire quantize/dequantize: K-folded rows over ('pod', 'data').
    * Newton–Schulz: the stacked-matrix axis over ('data',),
      replicated-or-rowwise per label (stacks that don't divide lower
      replicated).
    * outer update: shape-preserving specs mirroring the outer-state ZeRO
      layout itself (``outer_update_spec``), with dim -1 on 'model' only
      for TP-friendly archs (``outer_tp``) — matching the committed
      sharding is what keeps the donated TrainState aliased.
    * paged decode: batch slots (plus their page-table rows) over ('data',),
      KV pool and visit schedules replicated.

    Returns None on single-device worlds (kernels keep their plain
    single-device pallas_call path).
    """
    if mesh is None or mesh.devices.size <= 1:
        return None
    from repro.kernels.partition import KernelPartitioning

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    flash: tuple[str, ...] = ("data", "model")
    outer_tp = True
    if cfg is not None and sizes.get("model", 1) > 1:
        heads = getattr(cfg, "n_kv_heads", 0) or getattr(cfg, "n_heads", 0)
        if heads % sizes["model"]:
            # TP-unfriendly: keep attention replicated over 'model' so the
            # (unavoidable) gather happens at the layer boundary, not per
            # kernel call — same reasoning as activation_rules' attn_kv pin
            flash = ("data",)
        # outer_tp must track the STATE layout, not the kernel preference:
        # diloco_state_shardings drops 'model' for TP-unfriendly archs
        # (tp_friendly), and the outer-update specs must match the committed
        # sharding exactly or donation loses the aliased state buffers
        from repro.launch.steps import tp_friendly

        outer_tp = tp_friendly(cfg, mesh)
    return KernelPartitioning(mesh=mesh, flash_axes=flash, outer_tp=outer_tp)
