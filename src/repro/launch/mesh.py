"""Production mesh builders.

Single pod:  (data=16, model=16)            — 256 chips (one v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     — 512 chips

The `pod` axis IS the DiLoCo worker axis: fast ICI inside a pod carries the
per-step FSDP/tensor-parallel collectives; the slow cross-pod links carry
only the every-H-steps pseudogradient all-reduce.

Functions (not module constants) so importing never touches jax device
state — the dry-run must set XLA_FLAGS before any device query.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1, pod: int = 0) -> Mesh:
    """Small mesh over however many (host) devices exist — for tests."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
