from repro.launch.mesh import make_debug_mesh, make_production_mesh  # noqa: F401
