import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402  (the two lines above MUST precede any jax-touching import)
"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination on 512 placeholder host devices and record memory / cost /
collective evidence for the roofline analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --arch ... --multi-pod

Outputs one JSON per (arch, shape, mesh) under --out.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import (
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    config_for_shape,
    get_config,
    shape_supported,
)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_plans
from repro.models.api import build_model
from repro.roofline.analysis import (
    RooflineTerms,
    active_params,
    model_flops,
    parse_collective_bytes,
)
from repro.roofline.flops import (
    forward_flops,
    hbm_bytes,
    train_step_flops,
)
from repro.roofline.hlo import collective_bytes_corrected
from repro.utils.tree import tree_bytes, tree_count_params


def run_one(arch: str, shape: str, multi_pod: bool, sync_interval: int = 30,
            verbose: bool = True, plan_filter: str | None = None,
            inner_name: str = "muon", rounds_per_dispatch: int = 4,
            compression: str = "none", bits: int = 4,
            topk_frac: float = 0.01, attn_impl: str = "xla",
            ns_impl: str = "jnp", outer_kernel: bool = False,
            wire_impl: str = "jnp", straggler_sigma: float = 0.25,
            straggler_drop: float = 0.0) -> list[dict]:
    """Lower + compile all step plans for one (arch, shape, mesh) combo."""
    from repro.core.compression import CompressionConfig

    # Pallas calls carry no GSPMD partitioning rules of their own, but the
    # StepPlan machinery routes every call site through shard_map on the
    # plan's mesh (launch/sharding.kernel_specs), so 'pallas' backends lower
    # on the 512-device world too — a plan that still fails is recorded as
    # status=error with an error_path classifying which route broke
    cfg0 = get_config(arch).replace(attn_impl=attn_impl)
    if not shape_supported(cfg0, shape):
        return [{
            "arch": arch, "shape": shape, "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "skipped", "reason": f"{shape} not applicable (DESIGN.md §4)",
        }]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    records = []
    kw = {}
    # wire_impl='pallas' shard_maps the quantize/dequantize rows over
    # ('pod','data') — the same K-folded layout the wire buffers carry
    ccfg = CompressionConfig(
        kind=compression, bits=bits, topk_frac=topk_frac, wire_impl=wire_impl,
        collective="gather" if compression == "topk" else "a2a_rs_ag")
    dcfg = None
    if INPUT_SHAPES[shape].kind == "train":
        from repro.core.diloco import DiLoCoConfig

        n_pods = 2 if multi_pod else 1
        dcfg = DiLoCoConfig(n_workers=n_pods, sync_interval=sync_interval,
                            inner_name=inner_name, compression=ccfg,
                            ns_impl=ns_impl, outer_kernel=outer_kernel)
        kw["dcfg"] = dcfg
        kw["rounds_per_dispatch"] = rounds_per_dispatch
    plans = build_plans(cfg0, shape, mesh, **kw)
    # kernel-routing evidence shared by every record of this combo: which
    # backends were requested and which mesh axes each kernel shards over
    from repro.launch.sharding import kernel_specs

    kparts = kernel_specs(mesh, cfg0)
    uses_pallas = (attn_impl == "pallas" or ns_impl == "pallas"
                   or outer_kernel or wire_impl == "pallas")
    from repro.kernels.autotune import autotune_evidence

    kernels_evidence = {
        "attn_impl": attn_impl, "ns_impl": ns_impl,
        "outer_kernel": outer_kernel, "wire_impl": wire_impl,
        # which block-size knobs the committed autotune table resolved for
        # this shape's sequence length (empty 'tuned' = all constants)
        "autotune": autotune_evidence(config_for_shape(cfg0, shape),
                                      INPUT_SHAPES[shape].seq_len),
        "shard_map": kparts is not None,
        "partitioning": None if kparts is None else {
            "flash_axes": list(kparts.flash_axes),
            "quantize_axes": list(kparts.quantize_axes),
            "ns_axes": list(kparts.ns_axes),
            "paged_axes": list(kparts.paged_axes),
            "outer_tp": kparts.outer_tp,
        },
    }
    for plan in plans:
        if plan_filter and plan.name != plan_filter:
            continue
        rec = {
            "arch": arch, "shape": shape, "plan": plan.name,
            "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
            "inner": inner_name if plan.meta["kind"] in
            ("train", "sync", "round", "superstep") else None,
            "kernels": kernels_evidence,
        }
        t0 = time.time()
        try:
            with mesh:
                jitted = jax.jit(
                    plan.fn,
                    in_shardings=plan.in_shardings,
                    donate_argnums=plan.donate,
                )
                lowered = jitted.lower(*plan.args)
                compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, list):  # some jaxlibs return [dict]
                cost = cost[0] if cost else {}
            hlo_text = compiled.as_text()
            coll_flat = parse_collective_bytes(hlo_text)
            coll = collective_bytes_corrected(hlo_text)
            cfg = plan.meta["cfg"]
            params_abs = jax.eval_shape(lambda: build_model(cfg).init(jax.random.PRNGKey(0)))
            n_params = tree_count_params(params_abs)
            n_active = active_params(cfg, n_params)
            mf = model_flops(plan.meta["kind"], n_active, plan.meta["tokens_per_step"])
            flops_chip, bytes_chip = _analytic_terms(plan, cfg, params_abs, chips, shape)
            # measured cross-worker wire traffic of the program's outer
            # sync(s): actual wire-buffer sizes, not the ratio model
            comm = None
            wire_total = 0.0
            if dcfg is not None and plan.meta["kind"] in ("sync", "round", "superstep"):
                from repro.core.collectives import (
                    collective_bytes_tree,
                    measured_sync_bytes,
                )

                per_sync = measured_sync_bytes(params_abs, ccfg, dcfg.n_workers)
                syncs = (plan.meta.get("rounds_per_dispatch", 1)
                         if plan.meta["kind"] == "superstep" else 1)
                wire_total = float(per_sync) * syncs
                comm = {
                    "compression": {"kind": ccfg.kind, "bits": ccfg.bits,
                                    "topk_frac": ccfg.topk_frac},
                    "measured_bytes_per_sync_per_worker": int(per_sync),
                    "modeled_bytes_per_sync_per_worker": collective_bytes_tree(
                        params_abs, ccfg, dcfg.n_workers)["bytes_per_sync_per_worker"],
                    "syncs_in_program": int(syncs),
                    "measured_bytes_in_program": int(wire_total),
                }
            terms = RooflineTerms(
                flops=flops_chip,
                hlo_bytes=bytes_chip,
                collective_bytes=float(coll["total"]),
                chips=chips,
                model_flops=mf,
                amortize=float(plan.meta["amortize"]),
                wire_bytes=wire_total,
            )
            if plan.meta["kind"] in ("train", "round", "superstep", "prefill"):
                from repro.kernels.flash_attention import (
                    clamp_block,
                    visited_fraction,
                )

                S = INPUT_SHAPES[shape].seq_len
                rec["attention"] = {
                    "impl": cfg.attn_impl,
                    "block_q": clamp_block(cfg.attn_block_q, S),
                    "block_kv": clamp_block(cfg.attn_block_kv, S),
                    # block-granular execution: always for pallas, above the
                    # threshold for xla
                    "blockwise": bool(cfg.attn_impl == "pallas"
                                      or S >= cfg.blockwise_threshold),
                    # fraction of the block grid the visit schedule executes
                    # (causal diagonal + sliding window skipping)
                    "visited_fraction": round(visited_fraction(
                        S, cfg.attn_block_q, cfg.attn_block_kv,
                        causal=True, window=cfg.sliding_window), 4),
                }
            if plan.meta["kind"] in ("train", "round", "superstep"):
                # straggler evidence at the paper's K=16 scale: per-round
                # wall-clock p50/p99 when every worker draws a lognormal
                # latency multiplier and an i.i.d. drop coin, vs the
                # deterministic lockstep estimate — "what does p99 worker
                # latency cost at K=16?" (uses the plan's measured per-sync
                # wire bytes when the comm block carries them)
                from repro.core.wallclock import (
                    RunSpec,
                    StragglerModel,
                    straggler_stats,
                )

                ishape = INPUT_SHAPES[shape]
                wspec = RunSpec(
                    n_params=float(n_params), n_active_params=float(n_active),
                    batch_tokens=float(ishape.global_batch * ishape.seq_len),
                    seq_len=ishape.seq_len, n_steps=sync_interval,
                    sync_interval=sync_interval, n_workers=16,
                    wire_bytes_per_sync=float(
                        comm["measured_bytes_per_sync_per_worker"])
                    if comm is not None else 0.0)
                smodel = StragglerModel(sigma=straggler_sigma,
                                        drop_prob=straggler_drop)
                rec["straggler_wallclock"] = {
                    "n_workers": 16, "sigma": straggler_sigma,
                    "drop_prob": straggler_drop,
                    "bandwidth_gbit_s": 1.0,
                    **straggler_stats(wspec, 1e9, smodel),
                }
            donation = None
            if plan.name in ("round_step", "superstep"):
                donation = round_step_donation_report(plan.args[0], hlo_text,
                                                      mem, chips)
                # record first, then fail: on a lost alias the record keeps
                # status=error AND the donation diagnostics (rec.update in
                # the except handler preserves existing keys)
                rec["donation"] = donation
                if not donation["outer_state_aliased"]:
                    raise RuntimeError(
                        f"{plan.name} donation lost the outer-transform state: "
                        f"params {donation['outer_opt_param_indices']} not all "
                        f"in the input_output_alias map "
                        f"(alias {donation['alias_bytes_per_chip']} B/chip)")
            if comm is not None:
                rec["comm"] = comm
            rec.update({
                "status": "ok",
                "compile_s": round(time.time() - t0, 1),
                "n_params": n_params,
                "n_active_params": n_active,
                "memory": {
                    "argument_bytes": int(mem.argument_size_in_bytes),
                    "output_bytes": int(mem.output_size_in_bytes),
                    "temp_bytes": int(mem.temp_size_in_bytes),
                    "alias_bytes": int(mem.alias_size_in_bytes),
                    "peak_per_chip_gib": round(
                        (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                         - mem.alias_size_in_bytes) / 2**30, 3),
                },
                "collectives": {k: int(v) for k, v in coll.items()},
                "collectives_uncorrected": {k: int(v) for k, v in coll_flat.items()},
                "hlo_cost_analysis": {
                    "flops_per_chip_loop_body_once": float(cost.get("flops", 0.0)),
                    "bytes_accessed_loop_body_once": float(cost.get("bytes accessed", 0.0)),
                },
                "roofline": terms.as_dict(),
            })
        except Exception as e:  # noqa: BLE001 — record the failure verbatim
            # classify where the lowering broke: a pallas backend under
            # shard_map routing, a pallas backend with NO routing installed
            # (single-device-only legacy path), or plain GSPMD
            if uses_pallas:
                error_path = ("pallas-shard-map" if kparts is not None
                              else "pallas-unpartitioned")
            else:
                error_path = "gspmd"
            rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                        "error_path": error_path,
                        "traceback": traceback.format_exc()[-2000:]})
        if verbose:
            _print_record(rec)
        records.append(rec)
    return records


def round_step_donation_report(state_abs, hlo_text: str, mem, chips: int) -> dict:
    """GSPMD-aliasing evidence for the donated round/superstep plans
    (ROADMAP open item).

    Both plans donate the TrainState, so the sync-state buffers — outer
    params AND the outer-transform (pseudogradient chain) state — must come
    back via input/output aliasing, not fresh allocations. Two checks:

    * per-chip accounting: ``memory_analysis().alias_size_in_bytes`` (a
      per-device number) covers at least the outer params+opt shard;
    * the HLO ``input_output_alias`` map contains the ``outer_opt`` entry
      parameters (jit flattens the donated TrainState field-by-field, so the
      outer-transform state occupies a contiguous leaf-index range right
      after ``outer_params``). The check is byte-weighted: through the
      superstep's scan-over-R while loop XLA legitimately declines to alias
      O(kB) vector buffers (norm scales), so up to 1% of the outer-state
      bytes may escape aliasing — the parameter-sized buffers donation
      exists for must all alias.

    The report is **per-buffer**: every outer-params / outer-opt leaf is
    listed by its tree path with its bytes and aliasing verdict, so the
    escaped bytes are attributed to named buffers (``unaliased_buffers``)
    rather than a byte total.
    """
    import re

    def named_leaves(tree, start: int) -> list[dict]:
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        return [{
            "param_index": start + i,
            "path": jax.tree_util.keystr(path),
            "bytes": int(leaf.size * leaf.dtype.itemsize),
        } for i, (path, leaf) in enumerate(flat)]

    param_entries = named_leaves(state_abs["outer_params"], 0)
    opt_entries = named_leaves(state_abs["outer_opt"], len(param_entries))
    aliased = {int(g) for g in re.findall(
        r"\((\d+), \{[^}]*\}, \w+-alias\)", hlo_text)}
    for e in param_entries + opt_entries:
        e["aliased"] = e["param_index"] in aliased
    outer_opt_bytes = tree_bytes(state_abs["outer_opt"])
    outer_param_bytes = tree_bytes(state_abs["outer_params"])
    unaliased_opt_bytes = sum(e["bytes"] for e in opt_entries if not e["aliased"])
    alias = int(mem.alias_size_in_bytes)
    return {
        "alias_bytes_per_chip": alias,
        "outer_opt_bytes_global": int(outer_opt_bytes),
        "outer_params_bytes_global": int(outer_param_bytes),
        "outer_opt_unaliased_bytes": int(unaliased_opt_bytes),
        "aliased_param_count": len(aliased),
        "outer_opt_param_indices": [e["param_index"] for e in opt_entries],
        "buffers": param_entries + opt_entries,
        "unaliased_buffers": [
            {"path": e["path"], "bytes": e["bytes"]}
            for e in param_entries + opt_entries if not e["aliased"]],
        "outer_state_aliased": bool(
            unaliased_opt_bytes <= 0.01 * max(outer_opt_bytes, 1)
            and alias * chips >= (outer_opt_bytes + outer_param_bytes
                                  - 2 * unaliased_opt_bytes)),
    }


def _analytic_terms(plan, cfg, params_abs, chips: int, shape: str) -> tuple[float, float]:
    """Per-chip (flops, hbm_bytes) from the closed-form models (flops.py)."""
    from repro.configs import INPUT_SHAPES

    spec = INPUT_SHAPES[shape]
    kind = plan.meta["kind"]
    pbytes = tree_bytes(params_abs)
    act_elt = 2.0  # bf16 activations
    d_ff_active = cfg.d_ff * (cfg.experts_per_token + cfg.n_shared_experts) if cfg.n_experts else cfg.d_ff
    per_tok_layer = (8.0 * cfg.d_model + 2.0 * d_ff_active) * act_elt

    if kind in ("train", "round", "superstep"):
        dcfg = plan.meta["dcfg"]
        sf = train_step_flops(cfg, spec.seq_len, spec.global_batch, params_abs, dcfg.inner_name)
        # optimizer state per chip: m (+v for adamw / embeds)
        state_abs = plan.args[0]
        opt_bytes = tree_bytes(state_abs["inner_state"])
        act_bytes = spec.global_batch * spec.seq_len * cfg.n_layers * per_tok_layer
        # each worker's params are fully sharded within its pod (chips/K chips)
        chips_per_worker = chips / max(dcfg.n_workers, 1)
        total_bytes = hbm_bytes("train", param_bytes_chip=pbytes / chips_per_worker,
                                opt_state_bytes_chip=opt_bytes / chips,
                                act_bytes_chip=act_bytes / chips)
        if kind in ("round", "superstep"):
            # the fused round = H inner steps + one sync (elementwise terms);
            # a superstep is R such rounds in one dispatch
            H = dcfg.sync_interval
            R = plan.meta.get("rounds_per_dispatch", 1)
            n = tree_count_params(params_abs)
            sync_flops = 10.0 * n * 3.0
            sync_bytes = hbm_bytes("sync", param_bytes_chip=pbytes / chips * 4.0,
                                   opt_state_bytes_chip=tree_bytes(state_abs["outer_opt"]) / chips,
                                   act_bytes_chip=0.0)
            return (R * (sf.total * H + sync_flops) / chips,
                    R * (total_bytes * H + sync_bytes))
        return sf.total / chips, total_bytes
    if kind == "sync":
        state_abs = plan.args[0]
        n = tree_count_params(params_abs)
        flops = 10.0 * n * 3.0  # EF/compress + nesterov + reset, elementwise
        total_bytes = hbm_bytes("sync", param_bytes_chip=pbytes / chips * 4.0,
                                opt_state_bytes_chip=tree_bytes(state_abs["outer_opt"]) / chips,
                                act_bytes_chip=0.0)
        return flops / chips, total_bytes
    if kind == "prefill":
        f = forward_flops(cfg, spec.seq_len, spec.global_batch)
        act_bytes = spec.global_batch * spec.seq_len * cfg.n_layers * per_tok_layer
        total_bytes = hbm_bytes("prefill", param_bytes_chip=pbytes / chips,
                                opt_state_bytes_chip=0.0, act_bytes_chip=act_bytes / chips)
        return f / chips, total_bytes
    # decode
    f = forward_flops(cfg, spec.seq_len, spec.global_batch, T=1, kv_len=spec.seq_len)
    cache_bytes = tree_bytes(plan.args[1])
    act_bytes = spec.global_batch * cfg.n_layers * per_tok_layer
    total_bytes = hbm_bytes("decode", param_bytes_chip=pbytes / chips,
                            opt_state_bytes_chip=0.0, act_bytes_chip=act_bytes / chips,
                            cache_bytes_chip=cache_bytes / chips)
    return f / chips, total_bytes


def _print_record(rec: dict) -> None:
    if rec["status"] == "skipped":
        print(f"[SKIP] {rec['arch']} x {rec['shape']} ({rec['mesh']}): {rec['reason']}")
        return
    if rec["status"] == "error":
        print(f"[FAIL] {rec['arch']} x {rec['shape']} {rec['plan']} ({rec['mesh']}): {rec['error']}")
        return
    r = rec["roofline"]
    print(
        f"[ OK ] {rec['arch']:22s} {rec['shape']:12s} {rec['plan']:12s} {rec['mesh']:8s} "
        f"compile={rec['compile_s']:6.1f}s peak/chip={rec['memory']['peak_per_chip_gib']:8.3f}GiB "
        f"C={r['compute_s']:.3e}s M={r['memory_s']:.3e}s X={r['collective_s']:.3e}s "
        f"dom={r['dominant']:10s} useful={r['useful_flops_ratio']:.2f}"
    )


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ASSIGNED_ARCHS) + ["paper-416m", "paper-15.23b"])
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="every arch x shape")
    ap.add_argument("--plan", default=None, help="only this plan (train_step/sync_step/...)")
    from repro.optim import INNER_OPTIMIZERS

    ap.add_argument("--inner", default="muon", choices=list(INNER_OPTIMIZERS))
    ap.add_argument("--rounds-per-dispatch", type=int, default=4,
                    help="R of the superstep plan (rounds per dispatch)")
    ap.add_argument("--compression", default="none",
                    choices=["none", "topk", "quant"],
                    help="pseudogradient wire format for the train plans "
                         "(lowered via the jnp wire path; the comm block "
                         "records measured vs modeled bytes)")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--topk-frac", type=float, default=0.01)
    ap.add_argument("--attn-impl", default="xla", choices=["xla", "pallas"],
                    help="attention backend for the lowered plans; 'pallas' "
                         "shard_maps the fused kernel over the mesh "
                         "(batch x kv-heads -> 'data' x 'model'), so it "
                         "lowers on the 512-device world too")
    ap.add_argument("--ns-impl", default="jnp", choices=["jnp", "pallas"],
                    help="Newton-Schulz backend for the Muon inner steps; "
                         "'pallas' shard_maps the matrix stack over 'data'")
    ap.add_argument("--outer-kernel", action="store_true",
                    help="route the outer Nesterov descent through the fused "
                         "Pallas update kernel, shard_mapped over the flat "
                         "('pod','data','model') element axis")
    ap.add_argument("--wire-impl", default="jnp", choices=["jnp", "pallas"],
                    help="quantize/dequantize backend for the wire stages; "
                         "'pallas' shard_maps the row axis over "
                         "('pod','data')")
    ap.add_argument("--straggler-sigma", type=float, default=0.25,
                    help="lognormal sigma of the per-worker latency "
                         "multiplier in the straggler_wallclock evidence "
                         "block (p50/p99 round wall-clock at K=16)")
    ap.add_argument("--straggler-drop", type=float, default=0.0,
                    help="per-(round, worker) drop probability in the "
                         "straggler_wallclock evidence block (dropped "
                         "workers leave the round's slowest-worker max)")
    ap.add_argument("--autotune", default="on", choices=["on", "off"],
                    help="consult the committed kernel autotune table when "
                         "resolving block sizes ('off' restores the raw "
                         "constants); the resolution lands in every record's "
                         "kernels.autotune evidence block")
    ap.add_argument("--autotune-table", default=None,
                    help="path of the autotune JSON table (default: the "
                         "committed src/repro/kernels/autotune_table.json)")
    ap.add_argument("--out", default="results/dryrun")
    return ap


def main() -> None:
    args = build_parser().parse_args()
    from repro.kernels.autotune import configure

    configure(enabled=args.autotune == "on", table_path=args.autotune_table)

    archs = list(ASSIGNED_ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}__{args.inner}"
                if args.compression == "quant":
                    tag += f"__quant{args.bits}"
                elif args.compression == "topk":
                    tag += f"__topk{args.topk_frac}"
                kern_bits = []
                if args.attn_impl != "xla":
                    kern_bits.append(f"attn-{args.attn_impl}")
                if args.ns_impl != "jnp":
                    kern_bits.append(f"ns-{args.ns_impl}")
                if args.outer_kernel:
                    kern_bits.append("outerk")
                if args.wire_impl != "jnp":
                    kern_bits.append(f"wire-{args.wire_impl}")
                if kern_bits:
                    tag += "__" + "-".join(kern_bits)
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[CACHED] {tag}")
                    continue
                recs = run_one(arch, shape, mp, plan_filter=args.plan,
                               inner_name=args.inner,
                               rounds_per_dispatch=args.rounds_per_dispatch,
                               compression=args.compression, bits=args.bits,
                               topk_frac=args.topk_frac,
                               attn_impl=args.attn_impl, ns_impl=args.ns_impl,
                               outer_kernel=args.outer_kernel,
                               wire_impl=args.wire_impl,
                               straggler_sigma=args.straggler_sigma,
                               straggler_drop=args.straggler_drop)
                with open(path, "w") as f:
                    json.dump(recs, f, indent=2)


if __name__ == "__main__":
    main()
