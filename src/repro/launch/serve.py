"""Serving driver: paged-KV continuous batching or the dense-cache baseline.

Two engines (``--engine``):

* ``paged`` — ``repro.serving.PagedEngine``: fixed pool of KV pages
  (``--max-pages`` x ``--page-size``), continuous batching over
  ``--slots`` batch slots, single-dispatch batched prefill, and decode
  spans of ``--decode-steps-per-dispatch`` tokens per donated jitted
  call. Dense/MoE attention families only.
* ``naive`` — the seed's lockstep dense-cache loop (kept as the
  benchmark baseline), upgraded with batched prefill and with request
  ``context`` threaded into the cache. Serves every family, including
  recurrent-state (ssm/hybrid) and cross-attention (audio/vlm) models.

On CPU this serves reduced configs (examples/serve_batched.py); the same
driver lowers to the production mesh for the real deployment.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.models import build_model
from repro.serving import PagedEngine, Request, naive_generate


def generate(model, params, prompts: jax.Array, max_new: int, temperature: float = 0.0,
             context: jax.Array | None = None, rng: jax.Array | None = None,
             batched_prefill: bool = True):
    """prompts: [B, P] int32 -> tokens [B, P + max_new] (dense-cache path).

    Kept as the stable entry point; now delegates to
    :func:`repro.serving.naive_generate`, which threads ``context`` into
    the cache (the previous version dropped it — audio/VLM decode ran
    unconditioned) and prefills attention families in one dispatch.
    """
    return naive_generate(model, params, prompts, max_new,
                          temperature=temperature, context=context, rng=rng,
                          batched_prefill=batched_prefill)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="number of requests (paged: admitted across --slots)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--engine", choices=["naive", "paged"], default="paged",
                    help="paged: continuous batching over the KV page pool; "
                         "naive: lockstep dense-cache baseline")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV slots per page (paged engine)")
    ap.add_argument("--max-pages", type=int, default=128,
                    help="total pages in the pool, incl. reserved null page 0")
    ap.add_argument("--decode-steps-per-dispatch", type=int, default=8,
                    help="tokens decoded per jitted dispatch (lax.scan span)")
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent batch slots of the paged engine")
    ap.add_argument("--attn-impl", default="xla", choices=["xla", "pallas"],
                    help="decode attention backend: 'xla' or 'pallas' (fused "
                         "paged-decode kernel; shard_mapped over the mesh "
                         "when the engine is built with one)")
    return ap


def main() -> None:
    args = build_parser().parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    cfg = cfg.replace(attn_impl=args.attn_impl)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0, cfg.vocab)
    ctx = None
    if cfg.arch_type == "audio":
        ctx = jnp.zeros((args.batch, cfg.n_audio_frames, cfg.d_model))
    if cfg.arch_type == "vlm":
        ctx = jnp.zeros((args.batch, cfg.n_image_tokens, cfg.d_model))

    t0 = time.time()
    if args.engine == "paged":
        engine = PagedEngine(
            model, params, slots=args.slots, page_size=args.page_size,
            max_pages=args.max_pages,
            decode_steps_per_dispatch=args.decode_steps_per_dispatch,
            temperature=args.temperature, attn_impl=args.attn_impl, rng=rng)
        reqs = [Request(f"req{i}", tuple(int(t) for t in row), args.max_new)
                for i, row in enumerate(jax.device_get(prompts))]
        results = engine.run(reqs)
        dt = time.time() - t0
        sample = results["req0"][:8].tolist()
    else:
        toks = generate(model, params, prompts, args.max_new,
                        temperature=args.temperature, context=ctx, rng=rng)
        dt = time.time() - t0
        sample = toks[0, args.prompt_len: args.prompt_len + 8].tolist()
    n_new = args.batch * args.max_new
    print(f"[{args.engine}] generated {n_new} tokens in {dt:.2f}s "
          f"({n_new/dt:.1f} tok/s)")
    print("sample:", sample)


if __name__ == "__main__":
    main()
