"""Serving driver: batched prefill + decode with continuous token streaming.

On CPU this serves reduced configs (examples/serve_batched.py); the same
driver lowers to the production mesh for the real deployment. Demonstrates
the full request lifecycle: prefill a batch of prompts, then step the decode
loop with greedy/temperature sampling against the shared KV cache.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.models import build_model


def generate(model, params, prompts: jax.Array, max_new: int, temperature: float = 0.0,
             context: jax.Array | None = None, rng: jax.Array | None = None):
    """prompts: [B, P] int32 -> tokens [B, P + max_new]."""
    B, P = prompts.shape
    cache = model.init_cache(params, B, P + max_new)
    step = jax.jit(model.decode_step)

    # prefill by stepping the decode path (exactly the serving hot loop;
    # exercises cache writes at every position)
    tok = prompts[:, 0]
    out = [tok]
    for t in range(P + max_new - 1):
        logits, cache = step(params, cache, tok, jnp.int32(t))
        if t + 1 < P:
            tok = prompts[:, t + 1]
        else:
            if temperature > 0:
                rng, k = jax.random.split(rng)
                tok = jax.random.categorical(k, logits / temperature, axis=-1).astype(jnp.int32)
            else:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0, cfg.vocab)
    ctx = None
    if cfg.arch_type == "audio":
        ctx = jnp.zeros((args.batch, cfg.n_audio_frames, cfg.d_model))
    if cfg.arch_type == "vlm":
        ctx = jnp.zeros((args.batch, cfg.n_image_tokens, cfg.d_model))

    t0 = time.time()
    toks = generate(model, params, prompts, args.max_new,
                    temperature=args.temperature, context=ctx, rng=rng)
    dt = time.time() - t0
    n_new = args.batch * args.max_new
    print(f"generated {toks.shape} in {dt:.2f}s ({n_new/dt:.1f} tok/s)")
    print("sample:", toks[0, : args.prompt_len + 8].tolist())


if __name__ == "__main__":
    main()
