"""End-to-end training driver on the unified TrainEngine.

The driver is a thin scheduler around :class:`repro.engine.TrainEngine`:
``--rounds-per-dispatch R`` communication rounds (each H inner steps + the
outer pseudogradient-chain sync, streaming segments included) run as ONE
donated, jitted superstep that stays on device — per-round train/eval
losses come back in [R, H]/[R] device buffers and the Python layer only
generates batches, drains metrics asynchronously (the paper's smoothed-EMA
eval estimate + CSV logging ride under the accelerator's compute via
:func:`repro.engine.run_rounds`), and checkpoints. R is auto-clamped to
divide the run length and the checkpoint cadence; every dividing R replays
the identical arithmetic bit for bit. The DP baseline is the same engine
with the degenerate (K=1, H=1, no-outer) config.

Runs DiLoCo/MuLoCo on the synthetic LM data stream. On CPU this trains
reduced configs (examples/); on a TPU cluster the same driver runs the
production mesh — the engine threads the StepPlan shardings so both lower
from the same round builder.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
        --inner muon --workers 4 --sync-interval 6 --rounds 20
"""
from __future__ import annotations

import argparse
import csv
import dataclasses
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (
    load_checkpoint,
    load_latest_valid,
    save_round_checkpoint,
)
from repro.configs import get_config, reduce_config
from repro.core.compression import CompressionConfig
from repro.core.diloco import DiLoCoConfig
from repro.core.faults import CrashPlan, FaultPlan, parse_drop_schedule
from repro.core.health import HealthConfig
from repro.data import DataConfig, MarkovStream, batches_for_round, batches_for_span
from repro.engine import RecoveryPolicy, TrainEngine, run_rounds
from repro.models import build_model
from repro.optim import INNER_OPTIMIZERS, OUTER_OPTIMIZERS, OptimizerConfig

# paper §5 / App. F: smoothed eval loss
def smoothed_eval_loss(losses: list[float], steps: list[int], H: int, alpha: float = 0.2) -> float:
    s = None
    prev_t = None
    for loss, t in zip(losses, steps):
        if t % H:
            continue
        if s is None:
            s, prev_t = loss, t
            continue
        a = 1.0 - jnp.exp(-alpha * (t - prev_t) / H)
        s = float(a) * loss + (1.0 - float(a)) * s
        prev_t = t
    return s if s is not None else (losses[-1] if losses else float("nan"))


def make_diloco_cfg(args) -> DiLoCoConfig:
    comp = CompressionConfig(
        kind=args.compression,
        bits=args.bits,
        topk_frac=args.topk_frac,
        quant_mode=args.quant_mode,
        rowwise=args.rowwise,
        error_feedback=args.error_feedback,
        collective="gather" if args.compression == "topk" else "a2a_rs_ag",
    )
    # elastic execution is switched on by any fault knob: a drop probability,
    # a scripted drop schedule, or a delayed outer sync — the participation
    # mask + pending FIFO only enter the program when actually requested, so
    # the default path lowers the exact pre-elastic program
    elastic = args.drop_prob > 0 or bool(args.drop_schedule)
    return DiLoCoConfig(
        n_workers=args.workers,
        sync_interval=args.sync_interval,
        inner_name=args.inner,
        outer_name=args.outer,
        outer_lr=args.outer_lr,
        outer_momentum=args.outer_momentum,
        compression=comp,
        streaming_partitions=args.streaming,
        ns_impl=args.ns_impl,
        outer_kernel=args.outer_kernel,
        elastic=elastic,
        sync_delay=args.sync_delay,
        health=HealthConfig(
            enabled=args.health_sentinel == "on",
            spike_factor=args.health_spike_factor,
            warmup_rounds=args.health_warmup,
        ),
    )


def make_fault_plan(args, n_workers: int) -> FaultPlan | None:
    """The host-side participation-mask generator, or None for lockstep."""
    schedule = parse_drop_schedule(args.drop_schedule) if args.drop_schedule else None
    plan = FaultPlan(n_workers=n_workers, drop_prob=args.drop_prob,
                     schedule=schedule, seed=args.drop_seed)
    return None if plan.is_trivial else plan


def parse_mesh(spec: str):
    """'DxM' or 'PxDxM' -> a debug mesh over the host devices (P -> 'pod')."""
    from repro.launch.mesh import make_debug_mesh

    dims = [int(d) for d in spec.lower().split("x")]
    if len(dims) == 2:
        return make_debug_mesh(dims[0], dims[1])
    if len(dims) == 3:
        return make_debug_mesh(dims[1], dims[2], pod=dims[0])
    raise SystemExit(f"--mesh {spec!r}: expected DxM or PxDxM")


def train(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    # plumb --seq-len into the model config (single source of truth for the
    # data pipeline; clamps the sliding window so W never exceeds S) and the
    # attention execution knobs (--attn-impl routes the fused Pallas
    # flash-attention kernel exactly like --ns-impl routes Newton-Schulz)
    seq_len = args.seq_len or cfg.max_seq_len or 128
    cfg = cfg.replace(
        max_seq_len=seq_len,
        sliding_window=min(cfg.sliding_window, seq_len) if cfg.sliding_window else 0,
        attn_impl=args.attn_impl,
    )
    # block-size resolution order: autotune table (bitwise-gated best-known
    # configs, --autotune off restores the raw constants) < explicit CLI
    # overrides (None = not passed)
    from repro.kernels.autotune import configure, tuned_model_config

    configure(enabled=args.autotune == "on", table_path=args.autotune_table)
    if args.autotune == "on":
        cfg = tuned_model_config(cfg, seq_len)
    overrides = {k: v for k, v in (
        ("blockwise_threshold", args.blockwise_threshold),
        ("attn_block_q", args.attn_block_q),
        ("attn_block_kv", args.attn_block_kv)) if v is not None}
    if overrides:
        cfg = cfg.replace(**overrides)
    model = build_model(cfg)

    dcfg = make_diloco_cfg(args)
    total_steps = args.rounds * args.sync_interval
    icfg = OptimizerConfig(
        lr=args.lr, weight_decay=args.weight_decay, schedule=args.schedule,
        warmup_steps=max(total_steps // 100, 5), total_steps=total_steps,
        ns_period=args.ns_period,
    )

    # --mesh runs the SAME driver under the StepPlan layout: state and
    # batches committed to the mesh shardings, the worker axis vmapped over
    # 'pod', and every Pallas call site shard_mapped via the engine's
    # kernel_specs routing (so --attn-impl/--ns-impl/--outer-kernel pallas
    # are legal on multi-device worlds)
    mesh = parse_mesh(args.mesh) if args.mesh else None
    ekw: dict = {}
    if mesh is not None:
        from repro.launch.mesh import mesh_axis_sizes
        from repro.launch.steps import activation_rules, tp_friendly

        ekw = {"mesh": mesh,
               "rules": activation_rules(mesh, args.batch_per_worker, cfg,
                                         train=True),
               "spmd_axis": ("pod" if mesh_axis_sizes(mesh).get("pod", 0) > 1
                             else None)}
    engine = TrainEngine(model, dcfg, icfg, **ekw)
    rng = jax.random.PRNGKey(args.seed)
    state = engine.init(rng)
    # the state sharding pytree: on a mesh the resume path MUST re-place the
    # loaded leaves under the StepPlan layout (the default device_put would
    # silently land everything on one device and the first dispatch would
    # reshard — or OOM — at runtime)
    shardings = (engine.state_shardings(
        tensor_parallel=tp_friendly(cfg, mesh)) if mesh is not None else None)
    if mesh is not None:
        state = jax.device_put(state, shardings)

    start_round = 0
    resumed_from = None
    if args.resume == "auto":
        got = load_latest_valid(args.out, engine.abstract_state(),
                                shardings=shardings)
        if got is not None:
            state, start_round, resumed_from = got
    elif args.resume and os.path.exists(args.resume):
        state, start_round = load_checkpoint(args.resume, engine.abstract_state(),
                                             shardings=shardings)
        resumed_from = args.resume
    if resumed_from is not None:
        if mesh is not None:
            # assert the resumed leaves actually sit under the plan layout
            for leaf, want in zip(jax.tree.leaves(state),
                                  jax.tree.leaves(shardings)):
                assert leaf.sharding.is_equivalent_to(want, leaf.ndim), (
                    f"resumed leaf placed under {leaf.sharding}, "
                    f"expected {want}")
        print(f"resumed from {resumed_from} at round {start_round}")
        print(f"resume telemetry: resumed_from={os.path.basename(resumed_from)} "
              f"start_round={start_round}")

    data = MarkovStream(DataConfig(
        vocab=cfg.vocab, seq_len=cfg.max_seq_len,
        batch_per_worker=args.batch_per_worker, n_workers=dcfg.n_workers,
        seed=args.seed,
    ))
    eval_data = MarkovStream(DataConfig(
        vocab=cfg.vocab, seq_len=cfg.max_seq_len,
        batch_per_worker=args.batch_per_worker, n_workers=1, seed=args.seed + 10_000,
    ))

    def eval_batches_for(r0, n):
        # [n, B, S] held-out batches, one per round; the engine evaluates the
        # post-sync outer params inside the superstep program itself
        return jax.tree.map(lambda x: x[:, 0], eval_data.batch_stack(r0, n))

    os.makedirs(args.out, exist_ok=True)
    csv_path = os.path.join(args.out, "metrics.csv")
    header = ["round", "step", "train_loss", "eval_loss", "comm_bytes",
              "active_workers", "staleness", "health", "rollbacks", "wall_s"]
    losses, steps = [], []
    # Resume: reload the killed run's rows up to start_round so (a) the
    # smoothed-EMA eval estimate continues from the SAME history the
    # uninterrupted run would have (losses are logged at %.9g — exact f32
    # round-trip via np.float32, so the smoothing replays bit-identically)
    # and (b) the rewritten CSV drops any rows past the checkpoint we
    # restored (rounds the dead process logged but whose state was lost) —
    # the keystone invariant is a resumed metrics.csv tail byte-identical to
    # the uninterrupted run's.
    prior_rows: list[list[str]] = []
    if start_round > 0 and os.path.exists(csv_path):
        with open(csv_path, newline="") as f:
            rdr = csv.reader(f)
            for row in rdr:
                if row and row[0].isdigit() and int(row[0]) < start_round:
                    prior_rows.append(row)
        for row in prior_rows:
            losses.append(float(np.float32(row[3])))
            steps.append(int(row[1]))
    t_start = time.time()
    with open(csv_path, "w", newline="") as f:
        writer = csv.writer(f)
        writer.writerow(header)
        writer.writerows(prior_rows)
        f.flush()

        fault_plan = make_fault_plan(args, dcfg.n_workers)
        crash = CrashPlan(nan_round=args.inject_nan_round,
                          spike_round=args.inject_spike_round,
                          kill_round=args.inject_kill_round)
        telemetry: dict = {}

        def on_round(rec):
            losses.append(rec["eval_loss"])
            steps.append(rec["step"])
            # comm_bytes is the round's *measured* per-worker wire traffic,
            # drained from the engine's [R] device buffer (actual wire-buffer
            # sizes, not the modeled compression ratio); active_workers /
            # staleness are the elastic evidence (== K / 0 on lockstep runs),
            # health the sentinel's flag bitmask (0 when the sentinel is off)
            # and rollbacks the recovery count so far
            aw = rec.get("active_workers", float(dcfg.n_workers))
            st = rec.get("staleness", float(dcfg.sync_delay))
            writer.writerow([rec["round"], rec["step"], f"{rec['train_loss']:.9g}",
                             f"{rec['eval_loss']:.9g}", f"{rec['comm_bytes']:.0f}",
                             f"{aw:.0f}", f"{st:.0f}",
                             f"{rec.get('health', 0.0):.0f}",
                             telemetry.get("rollbacks", 0),
                             f"{time.time()-t_start:.1f}"])
            f.flush()
            if args.verbose:
                print(f"round {rec['round']:4d} step {rec['step']:6d} "
                      f"train {rec['train_loss']:.4f} eval {rec['eval_loss']:.4f} "
                      f"comm {rec['comm_bytes']:.2e}B active {aw:.0f}")
            # the SIGKILL injection fires only after the row is durably out:
            # the dead process leaves exactly a real crash's on-disk trail
            crash.maybe_kill(rec["round"])

        def on_state(r, st):
            save_round_checkpoint(args.out, st, r + 1,
                                  keep=args.keep_checkpoints)

        recovery = None
        if dcfg.health.enabled and args.checkpoint_every:
            template = engine.abstract_state()

            def restore():
                got = load_latest_valid(args.out, template, shardings=shardings)
                return None if got is None else (got[0], got[1])

            def scale_lr(scale):
                # escalation: rebuild the engine with the inner LR backed off
                # (same model/mesh/config — only icfg.lr changes)
                return TrainEngine(
                    model, dcfg, dataclasses.replace(icfg, lr=args.lr * scale),
                    **ekw)

            recovery = RecoveryPolicy(restore=restore,
                                      max_rollbacks=args.health_max_rollbacks,
                                      scale_lr=scale_lr)
            if start_round == 0 and not os.path.exists(
                    os.path.join(args.out, "ckpt_0.npz")):
                # a round-0 fault needs something to roll back to
                on_state(-1, state)

        # a poisoning injection edits state at a dispatch boundary; pin R=1
        # so the boundary IS the target round
        rpd = (1 if crash.needs_single_round_dispatch
               else args.rounds_per_dispatch)

        # Preemption: SIGTERM/SIGINT flip a flag the driver probes before
        # each dispatch; in-flight work finishes, metrics drain, and the
        # final checkpoint below makes the run resumable with --resume auto.
        stop = {"flag": False}

        def _graceful(signum, frame):
            stop["flag"] = True
            print(f"signal {signum}: draining in-flight dispatches, then "
                  f"writing a resumable checkpoint")

        old_handlers = {}
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                old_handlers[sig] = signal.signal(sig, _graceful)
            except ValueError:  # not the main thread (in-process tests)
                pass
        try:
            state, _history = run_rounds(
                engine, state,
                lambda r: batches_for_round(data, r, dcfg.sync_interval),
                args.rounds, start=start_round,
                rounds_per_dispatch=rpd,
                participation_for=fault_plan.masks if fault_plan is not None else None,
                span_batches_for=lambda r0, n: batches_for_span(
                    data, r0, dcfg.sync_interval, n),
                eval_batches_for=eval_batches_for,
                on_round=on_round,
                on_state=on_state if args.checkpoint_every else None,
                on_state_every=args.checkpoint_every,
                checkpoint_in_program=args.checkpoint_in_program,
                telemetry=telemetry,
                recovery=recovery,
                should_stop=lambda: stop["flag"],
                inject=None if crash.is_trivial else crash.apply,
            )
        finally:
            for sig, handler in old_handlers.items():
                signal.signal(sig, handler)

    if telemetry.get("preempted"):
        done = int(jax.device_get(state["round"]))
        path = save_round_checkpoint(args.out, state, done,
                                     keep=args.keep_checkpoints)
        print(f"preempted after round {done - 1}: wrote "
              f"{os.path.basename(path)}; resume with --resume auto")

    # the dispatch evidence line the CI single-dispatch smoke greps: with
    # --rounds-per-dispatch auto and no cadence pinning the whole run is ONE
    # donated device program, so dispatches must read 1
    print(f"dispatch telemetry: dispatches={telemetry.get('dispatches')} "
          f"rounds_per_dispatch={telemetry.get('rounds_per_dispatch')} "
          f"in_program_checkpoints={telemetry.get('in_program_checkpoints')} "
          f"rollbacks={telemetry.get('rollbacks')} "
          f"skipped_rounds={telemetry.get('skipped_rounds')} "
          f"preempted={telemetry.get('preempted')}")
    final = smoothed_eval_loss(losses, steps, dcfg.sync_interval)
    print(f"final smoothed eval loss: {final:.4f} "
          f"(floor={data.entropy_floor_nats():.4f} nats)")
    return {"final_loss": final, "losses": losses, "steps": steps, "state": state,
            "telemetry": telemetry}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", help="CPU-sized variant")
    ap.add_argument("--inner", default="muon", choices=list(INNER_OPTIMIZERS))
    ap.add_argument("--outer", default="nesterov", choices=list(OUTER_OPTIMIZERS))
    ap.add_argument("--ns-period", type=int, default=1,
                    help="muon_bp: orthogonalize every b steps (1 = plain Muon)")
    ap.add_argument("--outer-kernel", action="store_true",
                    help="route the outer descent through the fused Pallas kernel")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--sync-interval", type=int, default=6)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--rounds-per-dispatch",
                    type=lambda v: v if v == "auto" else int(v),
                    default="auto",
                    help="rounds per device dispatch (superstep length R), or "
                         "'auto' (the default): the dispatch cost model picks "
                         "R — the whole run as ONE device program when "
                         "unmeasured. Auto-clamped to divide the run and the "
                         "checkpoint cadence — any dividing R is "
                         "bitwise-identical")
    ap.add_argument("--lr", type=float, default=2e-2)
    ap.add_argument("--weight-decay", type=float, default=1e-4)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "constant"])
    ap.add_argument("--outer-lr", type=float, default=0.7)
    ap.add_argument("--outer-momentum", type=float, default=0.9)
    ap.add_argument("--batch-per-worker", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=0,
                    help="0 -> the arch config's max_seq_len (128 if unset)")
    ap.add_argument("--compression", default="none", choices=["none", "topk", "quant"])
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--quant-mode", default="linear", choices=["linear", "statistical"])
    ap.add_argument("--rowwise", action="store_true")
    ap.add_argument("--topk-frac", type=float, default=0.1)
    ap.add_argument("--error-feedback", action="store_true")
    ap.add_argument("--streaming", type=int, default=1, help="J partitions")
    ap.add_argument("--sync-delay", type=int, default=0,
                    help="apply the pseudogradient d rounds late (delayed/"
                         "overlapped outer sync): round r reduces the fresh "
                         "pseudogradient but descends on the one from round "
                         "r-d via an in-program FIFO; 0 = lockstep")
    ap.add_argument("--drop-prob", type=float, default=0.0,
                    help="per-(round, worker) i.i.d. drop probability "
                         "(elastic execution: dropped workers freeze, ship no "
                         "wire packet, and are excluded from the reduce)")
    ap.add_argument("--drop-schedule", default=None,
                    help="scripted drops 'round:worker[;round:worker...]', "
                         "e.g. '1:2;1:3;4:0' — each worker is dropped only "
                         "for the rounds listed and rejoins at the next sync")
    ap.add_argument("--drop-seed", type=int, default=0,
                    help="seed of the per-round drop draws (masks are a pure "
                         "function of (seed, round), so any "
                         "--rounds-per-dispatch chunking sees identical "
                         "faults)")
    ap.add_argument("--ns-impl", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--attn-impl", default="xla", choices=["xla", "pallas"],
                    help="attention backend: 'xla' (dense/blockwise) or "
                         "'pallas' (fused flash-attention kernel; interpret "
                         "mode off-TPU). Both run on a --mesh: pallas is "
                         "shard_mapped over the mesh by the engine's kernel "
                         "routing")
    ap.add_argument("--mesh", default=None,
                    help="run sharded on a DxM or PxDxM debug mesh over the "
                         "host devices (e.g. 2x2x2 with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8); "
                         "P is the 'pod' worker axis and must divide "
                         "--workers")
    ap.add_argument("--blockwise-threshold", type=int, default=None,
                    help="seq length at which attn_impl=xla switches from "
                         "dense softmax to blockwise online-softmax (default: "
                         "autotune table, else the config constant 4096)")
    ap.add_argument("--attn-block-q", type=int, default=None,
                    help="attention q-block rows (both impls; clamped to "
                         "divide the sequence; default: autotune table, else "
                         "the config constant 512)")
    ap.add_argument("--attn-block-kv", type=int, default=None,
                    help="attention kv-block rows (both impls; clamped to "
                         "divide the sequence; default: autotune table, else "
                         "the config constant 1024)")
    ap.add_argument("--autotune", default="on", choices=["on", "off"],
                    help="consult the committed kernel autotune table for "
                         "block sizes ('off' restores the raw constants); "
                         "entries are bitwise-gated at sweep time, so this "
                         "never changes any loss bit")
    ap.add_argument("--autotune-table", default=None,
                    help="path of the autotune JSON table (default: the "
                         "committed src/repro/kernels/autotune_table.json)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="results/train")
    ap.add_argument("--resume", default=None,
                    help="checkpoint to resume from: a file path, or 'auto' "
                         "to walk --out's round-stamped checkpoints newest to "
                         "oldest past truncated/corrupt/checksum-failing "
                         "files and restart from the freshest VALID one")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--keep-checkpoints", type=int, default=3,
                    help="retention: keep the newest N round-stamped "
                         "ckpt_<round>.npz files (older ones are pruned; the "
                         "LATEST manifest is rewritten atomically after every "
                         "save)")
    ap.add_argument("--health-sentinel", default="off", choices=["on", "off"],
                    help="in-program health sentinel: every round emits an "
                         "anomaly-flag metric (non-finite loss/psi, loss "
                         "spike vs a running EMA) drained with the other "
                         "metrics; with --checkpoint-every set, a flagged "
                         "round triggers rollback to the last valid "
                         "checkpoint + skip of the offending data span. "
                         "'off' (default) adds zero ops — the lowered "
                         "program is unchanged")
    ap.add_argument("--health-spike-factor", type=float, default=3.0,
                    help="flag a round whose mean train loss exceeds this "
                         "multiple of the running EMA")
    ap.add_argument("--health-warmup", type=int, default=3,
                    help="finite rounds observed before spike detection arms")
    ap.add_argument("--health-max-rollbacks", type=int, default=3,
                    help="rollback budget before escalation (halve the inner "
                         "LR, then abort)")
    ap.add_argument("--inject-nan-round", type=int, default=None,
                    help="fault injection: poison one worker-param element "
                         "with NaN at this round (forces "
                         "--rounds-per-dispatch 1 so the poison lands "
                         "exactly there)")
    ap.add_argument("--inject-spike-round", type=int, default=None,
                    help="fault injection: overwrite one worker-param "
                         "element with a large finite value at this round — "
                         "a silent-data-corruption loss spike (forces "
                         "--rounds-per-dispatch 1)")
    ap.add_argument("--inject-kill-round", type=int, default=None,
                    help="fault injection: SIGKILL this process the moment "
                         "the given round's metrics row hits the CSV (the "
                         "kill-resume harness; resume with --resume auto)")
    ap.add_argument("--checkpoint-in-program", action="store_true",
                    help="emit checkpoints from INSIDE the running device "
                         "program (io_callback) instead of between "
                         "dispatches, so --rounds-per-dispatch (and 'auto' = "
                         "the whole run) no longer needs to divide "
                         "--checkpoint-every")
    ap.add_argument("--verbose", action="store_true")
    return ap


if __name__ == "__main__":
    train(build_parser().parse_args())
