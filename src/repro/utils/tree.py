"""Pytree utilities used across the framework.

Everything here is jit-safe and works on arbitrary pytrees of arrays.
Paths follow ``jax.tree_util.keystr`` ("/a/b/0/c" style) so optimizer
partition rules and streaming-partition masks can match on names.
"""
from __future__ import annotations

import re
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import tree_util as jtu

PyTree = Any


def path_str(path) -> str:
    """Render a jax key path as 'a/b/0/c'."""
    parts = []
    for p in path:
        if isinstance(p, jtu.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jtu.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jtu.GetAttrKey):
            parts.append(str(p.name))
        else:  # pragma: no cover - future key types
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path(fn: Callable[[str, jax.Array], Any], tree: PyTree) -> PyTree:
    """Map ``fn(path_string, leaf)`` over a pytree."""
    return jtu.tree_map_with_path(lambda p, x: fn(path_str(p), x), tree)


def tree_paths(tree: PyTree) -> list[str]:
    flat, _ = jtu.tree_flatten_with_path(tree)
    return [path_str(p) for p, _ in flat]


def tree_leaves_with_paths(tree: PyTree) -> list[tuple[str, jax.Array]]:
    flat, _ = jtu.tree_flatten_with_path(tree)
    return [(path_str(p), x) for p, x in flat]


def tree_zeros_like(tree: PyTree, dtype=None) -> PyTree:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y, leafwise."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_dot(a: PyTree, b: PyTree) -> jax.Array:
    parts = jax.tree.map(lambda x, y: jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32)), a, b)
    return jax.tree.reduce(jnp.add, parts, jnp.float32(0.0))


def tree_norm(a: PyTree) -> jax.Array:
    return jnp.sqrt(tree_dot(a, a))


def tree_cosine(a: PyTree, b: PyTree, eps: float = 1e-12) -> jax.Array:
    return tree_dot(a, b) / (tree_norm(a) * tree_norm(b) + eps)


def tree_count_params(tree: PyTree) -> int:
    """Total number of elements (python int; works on ShapeDtypeStructs too)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = 1
        for d in leaf.shape:
            n *= int(d)
        total += n
    return total


def tree_bytes(tree: PyTree) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = 1
        for d in leaf.shape:
            n *= int(d)
        total += n * jnp.dtype(leaf.dtype).itemsize
    return total


def tree_unzip(tree_of_tuples: PyTree, n: int) -> tuple[PyTree, ...]:
    """Transpose a tree whose leaves are n-tuples into n trees.

    The standard unpack for ``jax.tree.map`` callbacks returning several
    values per leaf (new param + new state buffers, etc.)."""
    is_tup = lambda t: isinstance(t, tuple)  # noqa: E731
    return tuple(
        jax.tree.map(lambda t: t[i], tree_of_tuples, is_leaf=is_tup)  # noqa: B023
        for i in range(n))


def tree_select(mask_tree: PyTree, a: PyTree, b: PyTree) -> PyTree:
    """Leafwise where(mask, a, b); mask leaves are scalars or broadcastable bools."""
    return jax.tree.map(lambda m, x, y: jnp.where(m, x, y), mask_tree, a, b)


def tree_filter_paths(tree: PyTree, pattern: str) -> PyTree:
    """Boolean (python) mask tree: True where path matches the regex."""
    rx = re.compile(pattern)
    return tree_map_with_path(lambda p, x: bool(rx.search(p)), tree)


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def tree_all_finite(tree: PyTree) -> jax.Array:
    parts = [jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(tree)
             if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)]
    if not parts:
        return jnp.bool_(True)
    return jnp.stack(parts).all()
