"""Pseudogradient compressors (paper §2, §6.3): top-k sparsification and
linear / statistical quantization, each in global and row-wise variants.

The transform-stack stages at the bottom (``compress`` / ``error_feedback``)
are **wire-format-faithful**: they emit real wire buffers
(:mod:`repro.core.wire` — bit-packed uint8 codes + per-row metadata for
quantization, (index, value) pairs for top-k), and the EF residual is
computed against the actual reconstruction the receiver decodes from those
buffers. The collective layer (``repro.core.collectives``) moves and reduces
the buffers with exactly the paper's two quantize/dequantize points.

The standalone tensor functions above them (``topk_sparsify``,
``quantize_linear``, ``quantize_statistical``, ``ef_compress_tree``) keep
the original *value semantics* — they return the dequantized tensor the
receiver would reconstruct — and remain the oracles the property tests and
the analysis helpers use.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"  # none | topk | quant
    # top-k
    topk_frac: float = 0.01  # fraction of entries kept
    # quantization
    bits: int = 4
    quant_mode: str = "linear"  # linear | statistical
    rowwise: bool = False
    # error feedback (Karimireddy et al., 2019; paper Alg. 2)
    error_feedback: bool = False
    ef_decay: float = 0.9
    # collective model: 'a2a_rs_ag' = paper's all-to-all reduce-scatter +
    # ring all-gather (2 quantizations); 'gather' = all-gather + local
    # reduce (1 quantization, used for top-k)
    collective: str = "a2a_rs_ag"
    # wire-buffer backend for linear quantization: 'pallas' routes encode /
    # decode through the fused rowwise kernels (bit-identical to 'jnp' under
    # jit; on a mesh the rows shard_map over ('pod','data') via the kernel
    # routing). Statistical quantization and top-k are always jnp.
    wire_impl: str = "pallas"

    def compression_ratio(self) -> float:
        """Approximate wire-bytes ratio vs fp32 — the *modeled* number.

        Ignores metadata rows, index widths, and bit-packing padding; the
        measured accounting (``collectives.measured_sync_bytes``, computed
        from the actual wire buffers) supersedes it wherever buffers exist.
        """
        if self.kind == "none":
            return 1.0
        if self.kind == "topk":
            # value (fp32) + index (~log2 n ~ 32 bits) per kept entry
            return self.topk_frac * 2.0
        if self.kind == "quant":
            return self.bits / 32.0
        raise ValueError(self.kind)


# ---------------------------------------------------------------------------
# Top-k sparsification
# ---------------------------------------------------------------------------


def topk_sparsify(x: jax.Array, frac: float) -> jax.Array:
    """Keep exactly k = ceil(frac * n) largest-|.| entries, zero the rest."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    k = max(int(round(frac * n)), 1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    mask = jnp.zeros((n,), bool).at[idx].set(True)
    return jnp.where(mask, flat, 0).reshape(x.shape)


# ---------------------------------------------------------------------------
# Linear quantization
# ---------------------------------------------------------------------------


def _row_reduce(x: jax.Array, fn, rowwise: bool):
    if rowwise and x.ndim >= 2:
        return fn(x, axis=-1, keepdims=True)
    return fn(x)


def quantize_linear(x: jax.Array, bits: int, rowwise: bool = False) -> jax.Array:
    """Uniform levels over [min, max] (global or per last-axis row)."""
    x32 = x.astype(jnp.float32)
    lo = _row_reduce(x32, jnp.min, rowwise)
    hi = _row_reduce(x32, jnp.max, rowwise)
    nlevels = (1 << bits) - 1
    scale = (hi - lo) / nlevels
    scale = jnp.where(scale <= 0, 1.0, scale)
    q = jnp.round((x32 - lo) / scale)
    return (lo + q * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Statistical (quantile codebook) quantization
# ---------------------------------------------------------------------------


def quantize_statistical(x: jax.Array, bits: int, rowwise: bool = False) -> jax.Array:
    """Codebook levels at empirical quantiles (i+0.5)/2^bits; nearest-level
    assignment via midpoint bucketing."""
    x32 = x.astype(jnp.float32)
    nlevels = 1 << bits
    qs = (jnp.arange(nlevels, dtype=jnp.float32) + 0.5) / nlevels

    def quantize_vec(v):  # [n] -> [n]
        levels = jnp.quantile(v, qs)  # [nlevels], sorted
        mids = 0.5 * (levels[1:] + levels[:-1])
        code = jnp.searchsorted(mids, v)
        return levels[code]

    if rowwise and x.ndim >= 2:
        rows = x32.reshape(-1, x32.shape[-1])
        out = jax.vmap(quantize_vec)(rows).reshape(x32.shape)
    else:
        out = quantize_vec(x32.reshape(-1)).reshape(x32.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------


def compress_tensor(x: jax.Array, cfg: CompressionConfig) -> jax.Array:
    if cfg.kind == "none":
        return x
    if cfg.kind == "topk":
        return topk_sparsify(x, cfg.topk_frac)
    if cfg.kind == "quant":
        fn = quantize_linear if cfg.quant_mode == "linear" else quantize_statistical
        return fn(x, cfg.bits, cfg.rowwise)
    raise ValueError(f"unknown compressor {cfg.kind!r}")


def compress_tree(tree: PyTree, cfg: CompressionConfig) -> PyTree:
    if cfg.kind == "none":
        return tree
    return jax.tree.map(lambda x: compress_tensor(x, cfg), tree)


# ---------------------------------------------------------------------------
# Error feedback (paper Alg. 2 lines 13-17)
# ---------------------------------------------------------------------------


def ef_compress_tree(delta: PyTree, residual: PyTree, cfg: CompressionConfig) -> tuple[PyTree, PyTree]:
    """E <- beta*E + delta; comm = C(E); E <- E - comm. Returns (comm, E)."""

    def per_leaf(d, e):
        acc = cfg.ef_decay * e.astype(jnp.float32) + d.astype(jnp.float32)
        comm = compress_tensor(acc, cfg)
        return comm.astype(d.dtype), (acc - comm)

    out = jax.tree.map(per_leaf, delta, residual)
    is_tup = lambda t: isinstance(t, tuple)  # noqa: E731
    comm = jax.tree.map(lambda t: t[0], out, is_leaf=is_tup)
    new_res = jax.tree.map(lambda t: t[1], out, is_leaf=is_tup)
    return comm, new_res


# ---------------------------------------------------------------------------
# Transform-stack stages (the worker side of the pseudogradient chain).
# These are wire-format-faithful: they emit repro.core.wire packets, which
# the reduce stage (collectives.reduce_mean) moves and decodes.
# ---------------------------------------------------------------------------


def compress(cfg: CompressionConfig):
    """Stateless worker-side compression on [K, ...]-stacked deltas.

    Emits the Q1 / top-k **wire buffers** (the K axis folds into the row
    axis, so one fused kernel call encodes every worker); ``kind='none'``
    passes the dense deltas through untouched (bit-exact legacy path).
    """
    from repro.core.wire import encode_tree
    from repro.optim.transform import stateless

    if cfg.kind == "none":
        return stateless(lambda deltas, _params: deltas)
    return stateless(lambda deltas, _params: encode_tree(deltas, cfg, batch_ndim=1))


def error_feedback(cfg: CompressionConfig):
    """Error-feedback compression as a stateful transform on [K, ...] deltas.

    State is the K-stacked residual tree E (allocated by ``diloco_init`` in
    the optimizer ``state_dtype``). Per Alg. 2: ``E <- beta*E + delta``, the
    **wire buffers** ``W = Enc(E)`` are emitted downstream, and the new
    residual is ``E - Dec(W)`` — computed against the *actual reconstruction
    the receiver decodes from the wire*, not a value-semantics stand-in.
    The streaming-sync merge (untouched partitions keep their residuals)
    lives in the outer optimizer, which sees the partition mask.
    """
    from repro.core.wire import decode_leaf, encode_leaf
    from repro.optim.transform import Transform

    def init(stacked_template: PyTree) -> PyTree:
        return jax.tree.map(jnp.zeros_like, stacked_template)

    def update(deltas: PyTree, residuals: PyTree, params: PyTree):
        def per_leaf(d, e):
            acc = cfg.ef_decay * e.astype(jnp.float32) + d.astype(jnp.float32)
            w = encode_leaf(acc, cfg, batch_ndim=1)
            recon = decode_leaf(w, impl=cfg.wire_impl)
            return w, acc - recon

        out = jax.tree.map(per_leaf, deltas, residuals)
        is_pair = lambda t: isinstance(t, tuple)  # noqa: E731
        comm = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
        new_res = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
        return comm, new_res

    return Transform(init=init, update=update)
