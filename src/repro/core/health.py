"""In-program health sentinel: per-round anomaly flags at scan-carry cost.

The superstep scan already stacks per-round metric buffers (`[R]` losses,
comm bytes, ...). The sentinel rides the same mechanism: when enabled, the
round function folds a tiny ``{"ema", "n"}`` running-statistics dict through
the TrainState carry and emits one extra ``[R]`` float32 buffer of per-round
**flag bitmasks**:

  * bit 1 — a non-finite value appeared in the round's inner losses;
  * bit 2 — the pseudogradient's sum-of-squares is non-finite (a NaN/Inf
    reached the outer optimizer's input);
  * bit 4 — the round's mean loss spiked above ``spike_factor`` x the
    running EMA (only after ``warmup_rounds`` finite rounds, so cold-start
    descent never trips it).

The driver drains the buffer with the other metrics and hands nonzero flags
to the :class:`repro.engine.recovery.RecoveryPolicy` (rollback to the last
valid checkpoint + skip the offending span), or just records them when no
policy is armed.

Cost and parity: disabled (the default) the TrainState has no health leaf
and the round function traces zero extra ops — the lowered program is
*unchanged*, preserving the bitwise pins of PRs 1-9. Enabled, the additions
are two scalar carries and three reductions per round; they read the losses
and psi but never feed back into the parameter computation, so the training
arithmetic itself is untouched either way.

Because the EMA lives in the TrainState, it is checkpointed with everything
else — a killed-and-resumed run replays identical spike decisions, keeping
the bitwise-resume invariant intact with the sentinel armed.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

# flag bits in the per-round health buffer
FLAG_NONFINITE_LOSS = 1
FLAG_NONFINITE_PSI = 2
FLAG_LOSS_SPIKE = 4


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    enabled: bool = False
    spike_factor: float = 3.0  # flag when mean loss > factor * running EMA
    ema_alpha: float = 0.2  # EMA weight of the newest round's mean loss
    warmup_rounds: int = 3  # finite rounds before spike detection arms


def health_init(hcfg: HealthConfig) -> PyTree | None:
    """The carry dict ({"ema","n"} scalars), or None when disabled."""
    if not hcfg.enabled:
        return None
    return {"ema": jnp.zeros((), jnp.float32), "n": jnp.zeros((), jnp.int32)}


def health_update(hcfg: HealthConfig, health: PyTree, losses: jax.Array,
                  psi: PyTree) -> tuple[PyTree, jax.Array]:
    """Fold one round's losses ([H]) and pseudogradient into the running
    stats; returns ``(new_health, flag)`` with ``flag`` the f32 bitmask.

    The EMA only ingests finite mean losses (a NaN round must not poison the
    detector that is supposed to catch the next one), and ``n`` counts those
    finite rounds so warmup is measured in usable observations.
    """
    m = jnp.mean(losses.astype(jnp.float32))
    finite_m = jnp.isfinite(m)
    psi_ss = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                 for l in jax.tree.leaves(psi))
    bad_loss = ~jnp.isfinite(jnp.sum(losses.astype(jnp.float32)))
    bad_psi = ~jnp.isfinite(psi_ss)
    warm = health["n"] >= hcfg.warmup_rounds
    spike = warm & finite_m & (m > hcfg.spike_factor * health["ema"])
    flag = (FLAG_NONFINITE_LOSS * bad_loss.astype(jnp.float32)
            + FLAG_NONFINITE_PSI * bad_psi.astype(jnp.float32)
            + FLAG_LOSS_SPIKE * spike.astype(jnp.float32))
    a = jnp.float32(hcfg.ema_alpha)
    ema_next = jnp.where(health["n"] == 0, m, (1 - a) * health["ema"] + a * m)
    new = {
        "ema": jnp.where(finite_m, ema_next, health["ema"]),
        "n": health["n"] + finite_m.astype(jnp.int32),
    }
    return new, flag
