"""Streaming DiLoCo partitioned communication (Douillard et al., 2025; §6.4).

The model's parameters are split into J partitions; partition j performs its
outer sync at inner-step offsets j*H/J (mod H), cutting *peak* bandwidth by J
while total communication is unchanged.

Because layers are stored stacked ([L, ...] leading axis), a layer partition
is a broadcastable boolean mask over the L axis. Non-stacked leaves (embed,
head, final norms, shared blocks) are assigned whole-leaf to partitions
round-robin by path hash. Masks are float32 {0,1} and broadcast against each
leaf, so a masked outer update is a single `where`.
"""
from __future__ import annotations

import zlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_map_with_path

PyTree = Any


def streaming_masks(params: PyTree, n_partitions: int, layer_prefixes: tuple[str, ...] = ("layers", "self_layers", "cross_layers", "decoder", "encoder")) -> list[PyTree]:
    """Return J mask trees; elementwise they sum to 1 across partitions."""
    J = n_partitions

    def leaf_mask(path: str, leaf, j: int):
        is_stacked = any(path.startswith(p) or f"/{p}/" in path for p in layer_prefixes)
        if is_stacked and len(leaf.shape) >= 1 and leaf.shape[0] > 1:
            L = leaf.shape[0]
            layer_ids = jnp.arange(L)
            part = (layer_ids * J) // L  # contiguous layer ranges
            m = (part == j).astype(jnp.float32)
            return m.reshape((L,) + (1,) * (len(leaf.shape) - 1))
        # whole-leaf assignment, deterministic by path
        owner = zlib.crc32(path.encode()) % J
        return jnp.float32(1.0 if owner == j else 0.0)

    return [tree_map_with_path(lambda p, x: leaf_mask(p, x, j), params) for j in range(J)]


def masked_update(mask: PyTree, new: PyTree, old: PyTree) -> PyTree:
    """new where mask else old (mask broadcast per leaf)."""
    return jax.tree.map(
        lambda m, n, o: (m * n.astype(jnp.float32) + (1.0 - m) * o.astype(jnp.float32)).astype(o.dtype),
        mask, new, old,
    )


def assert_masks_partition(masks: list[PyTree]) -> bool:
    """Check masks tile the parameter set exactly once (test helper)."""
    total = jax.tree.map(lambda *ms: sum(jnp.broadcast_to(m, ()).astype(jnp.float32) if m.ndim == 0 else m for m in ms), *masks)
    ok = all(bool(jnp.all(jnp.isclose(t, 1.0))) for t in jax.tree.leaves(total))
    return ok
