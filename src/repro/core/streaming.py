"""Streaming DiLoCo partitioned communication (Douillard et al., 2025; §6.4).

The model's parameters are split into J partitions; partition j performs its
outer sync at inner-step offsets j*H/J (mod H), cutting *peak* bandwidth by J
while total communication is unchanged.

Because layers are stored stacked ([L, ...] leading axis), a layer partition
is a broadcastable boolean mask over the L axis. Non-stacked leaves (embed,
head, final norms, shared blocks) are assigned whole-leaf to partitions
round-robin by path hash. Masks are float32 {0,1} and broadcast against each
leaf, so a masked outer update is a single `where`.
"""
from __future__ import annotations

import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import tree_map_with_path

PyTree = Any


def streaming_masks(params: PyTree, n_partitions: int, layer_prefixes: tuple[str, ...] = ("layers", "self_layers", "cross_layers", "decoder", "encoder")) -> list[PyTree]:
    """Return J mask trees; elementwise they sum to 1 across partitions."""
    J = n_partitions

    def leaf_mask(path: str, leaf, j: int):
        is_stacked = any(path.startswith(p) or f"/{p}/" in path for p in layer_prefixes)
        if is_stacked and len(leaf.shape) >= 1 and leaf.shape[0] > 1:
            L = leaf.shape[0]
            layer_ids = jnp.arange(L)
            part = (layer_ids * J) // L  # contiguous layer ranges
            m = (part == j).astype(jnp.float32)
            return m.reshape((L,) + (1,) * (len(leaf.shape) - 1))
        # whole-leaf assignment, deterministic by path
        owner = zlib.crc32(path.encode()) % J
        return jnp.float32(1.0 if owner == j else 0.0)

    return [tree_map_with_path(lambda p, x: leaf_mask(p, x, j), params) for j in range(J)]


def subset_plan(mask_leaf, leaf_shape: tuple, ccfg) -> tuple[str, np.ndarray | None]:
    """Classify a concrete partition-mask leaf for wire-row subsetting.

    Returns ``(plan, idx)`` with plan one of:

    * ``'all'``    — the segment owns the whole leaf (encode it whole);
    * ``'skip'``   — the segment owns nothing (encode nothing at all);
    * ``'rows'``   — stacked-layer mask whose owned L-rows can be gathered
      into a *smaller* wire buffer without changing any wire row: only when
      the compressor quantizes per last-axis row (``kind='quant'`` +
      ``rowwise``) and the leaf is >= 2-D, so L-subsetting keeps every wire
      row whole and the per-segment byte totals sum exactly to the dense
      single-sync total;
    * ``'legacy'`` — partial ownership that would split wire rows (global
      quant rows span the L axis; top-k's k is rounded per leaf): keep the
      full-size masked encode, accounted at the masked-row fraction.

    Masks must be concrete (they are closure constants of the jitted round);
    a traced mask disqualifies subsetting at the caller.
    """
    m = np.asarray(mask_leaf)
    if m.ndim == 0:
        return ("all" if m > 0 else "skip"), None
    rows = m.reshape(m.shape[0], -1)  # stacked masks broadcast (L, 1, ..)
    assert (rows.min(axis=1) == rows.max(axis=1)).all(), (
        "partition mask rows must be constant along non-leading axes "
        "(streaming_masks produces (L, 1, ...) broadcasts); a mixed row "
        "cannot be row-subset without dropping owned entries")
    idx = np.nonzero(rows[:, 0] > 0)[0]
    if idx.size == m.shape[0]:
        return "all", None
    if idx.size == 0:
        return "skip", None
    if ccfg.kind == "quant" and ccfg.rowwise and len(leaf_shape) >= 2:
        return "rows", idx
    return "legacy", None


def masked_update(mask: PyTree, new: PyTree, old: PyTree) -> PyTree:
    """new where mask else old (mask broadcast per leaf)."""
    return jax.tree.map(
        lambda m, n, o: (m * n.astype(jnp.float32) + (1.0 - m) * o.astype(jnp.float32)).astype(o.dtype),
        mask, new, old,
    )


def assert_masks_partition(masks: list[PyTree]) -> bool:
    """Check masks tile the parameter set exactly once (test helper)."""
    total = jax.tree.map(lambda *ms: sum(jnp.broadcast_to(m, ()).astype(jnp.float32) if m.ndim == 0 else m for m in ms), *masks)
    ok = all(bool(jnp.all(jnp.isclose(t, 1.0))) for t in jax.tree.leaves(total))
    return ok
