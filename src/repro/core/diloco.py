"""DiLoCo / MuLoCo: the paper's algorithm as a composable JAX module.

Algorithm 1/2 of the paper, faithfully:

  * K workers each run H local steps of the **inner optimizer**
    (AdamW -> DiLoCo, Muon -> MuLoCo) on their own data shard;
  * every H steps, worker deltas Δ_k = θ_outer − θ_k are (optionally
    EF-compressed and) averaged into the pseudogradient Ψ;
  * the **outer** Nesterov-SGD applies Ψ to the outer params, which are then
    broadcast back to all workers.

Worker state is stacked on a leading K axis. On the production mesh this axis
is sharded over `pod`, so the H inner steps incur **zero cross-pod traffic**
and the Ψ-average is the only cross-pod all-reduce — DiLoCo's communication
pattern expressed purely through shardings. On CPU the same code simulates
any K via vmap. Streaming (partitioned) sync and compressed collectives plug
in through :mod:`repro.core.streaming` / :mod:`repro.core.collectives`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.collectives import reduce_pseudogradients
from repro.core.compression import CompressionConfig, compress_tree, ef_compress_tree
from repro.core.streaming import masked_update, streaming_masks
from repro.models.api import Model
from repro.optim import OptimizerConfig, make_inner_optimizer, nesterov_init, nesterov_step
from repro.utils.tree import tree_zeros_like

PyTree = Any


@dataclasses.dataclass(frozen=True)
class DiLoCoConfig:
    n_workers: int = 8  # K
    sync_interval: int = 30  # H
    inner_name: str = "muon"  # 'muon' -> MuLoCo, 'adamw' -> DiLoCo
    outer_lr: float = 0.7  # eta_out (paper Fig. 22 optima)
    outer_momentum: float = 0.9  # mu
    compression: CompressionConfig = dataclasses.field(default_factory=CompressionConfig)
    streaming_partitions: int = 1  # J (1 = no streaming)
    ns_impl: str = "jnp"

    @property
    def is_muloco(self) -> bool:
        return self.inner_name == "muon"


def make_optimizer(dcfg: DiLoCoConfig, inner_cfg: OptimizerConfig):
    kw = {"ns_impl": dcfg.ns_impl} if dcfg.inner_name == "muon" else {}
    return make_inner_optimizer(dcfg.inner_name, inner_cfg, **kw)


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------


def diloco_init(model: Model, dcfg: DiLoCoConfig, inner_cfg: OptimizerConfig, rng: jax.Array) -> PyTree:
    params = model.init(rng)
    K = dcfg.n_workers
    worker_params = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (K, *p.shape)), params)
    opt = make_optimizer(dcfg, inner_cfg)
    inner_state = jax.vmap(opt.init)(worker_params)
    state = {
        "outer_params": params,
        "outer_opt": nesterov_init(params, state_dtype=jnp.dtype(inner_cfg.state_dtype)),
        "worker_params": worker_params,
        "inner_state": inner_state,
        "round": jnp.zeros((), jnp.int32),
    }
    if dcfg.compression.error_feedback:
        sdt = jnp.dtype(inner_cfg.state_dtype)
        state["ef"] = jax.tree.map(lambda p: jnp.zeros((K, *p.shape), sdt), params)
    return state


# ---------------------------------------------------------------------------
# Inner step (runs every step; no cross-worker communication)
# ---------------------------------------------------------------------------


def inner_step(model: Model, opt, state: PyTree, batch: PyTree,
               spmd_axis: str | None = None) -> tuple[PyTree, dict]:
    """One local optimizer step on every worker. batch leaves: [K, B/K, ...].

    ``spmd_axis='pod'`` tells GSPMD the vmapped worker axis lives on the pod
    mesh axis, so activation sharding constraints inside the model compose
    with the worker dimension on the production mesh."""

    def one(params_k, inner_k, batch_k):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params_k, batch_k)
        new_p, new_s = opt.step(params_k, grads, inner_k)
        return new_p, new_s, loss

    new_wp, new_is, losses = jax.vmap(one, spmd_axis_name=spmd_axis)(
        state["worker_params"], state["inner_state"], batch)
    new_state = dict(state)
    new_state["worker_params"] = new_wp
    new_state["inner_state"] = new_is
    return new_state, {"loss": jnp.mean(losses), "loss_per_worker": losses}


# ---------------------------------------------------------------------------
# Outer step (the only cross-worker communication)
# ---------------------------------------------------------------------------


def compute_deltas(state: PyTree) -> PyTree:
    """Δ_k = θ_outer − θ_k, stacked [K, ...] (paper Alg. 1 line 9)."""
    return jax.tree.map(
        lambda o, w: o.astype(jnp.float32)[None] - w.astype(jnp.float32),
        state["outer_params"], state["worker_params"],
    )


def outer_step(dcfg: DiLoCoConfig, state: PyTree, mask: PyTree | None = None) -> tuple[PyTree, PyTree]:
    """Communicate + outer Nesterov update (+ worker reset). Returns (state, Ψ)."""
    ccfg = dcfg.compression
    deltas = compute_deltas(state)
    if mask is not None:
        deltas = jax.tree.map(lambda m, d: m[None] * d if m.ndim else m * d, mask, deltas)

    new_state = dict(state)
    if ccfg.error_feedback and ccfg.kind != "none":
        comm, new_ef = jax.vmap(lambda d, e: ef_compress_tree(d, e, ccfg))(deltas, state["ef"])
        if mask is not None:  # untouched partitions keep their residuals
            new_ef = jax.tree.map(
                lambda m, ne, oe: jnp.where((m[None] if m.ndim else m) > 0, ne, oe),
                mask, new_ef, state["ef"],
            )
        new_state["ef"] = new_ef
    else:
        comm = jax.vmap(lambda d: compress_tree(d, ccfg))(deltas)

    psi = reduce_pseudogradients(comm, ccfg)  # mean over K (+ Q2 for a2a quant)

    cand_params, cand_opt = nesterov_step(
        state["outer_params"], psi, state["outer_opt"],
        lr=dcfg.outer_lr, momentum=dcfg.outer_momentum,
    )
    if mask is None:
        new_outer, new_opt = cand_params, cand_opt
    else:
        new_outer = masked_update(mask, cand_params, state["outer_params"])
        new_opt = {"u": masked_update(mask, cand_opt["u"], state["outer_opt"]["u"])}

    # broadcast synced params back to workers (masked portions only)
    def reset(o, w, m=None):
        ob = jnp.broadcast_to(o[None].astype(w.dtype), w.shape)
        if m is None:
            return ob
        mm = m[None] if m.ndim else m
        return (mm * ob.astype(jnp.float32) + (1 - mm) * w.astype(jnp.float32)).astype(w.dtype)

    if mask is None:
        new_workers = jax.tree.map(reset, new_outer, state["worker_params"])
    else:
        new_workers = jax.tree.map(lambda o, w, m: reset(o, w, m), new_outer, state["worker_params"], mask)

    new_state["outer_params"] = new_outer
    new_state["outer_opt"] = new_opt
    new_state["worker_params"] = new_workers
    new_state["round"] = state["round"] + 1
    return new_state, psi


# ---------------------------------------------------------------------------
# Full round(s): H inner steps + sync (jit-able end to end)
# ---------------------------------------------------------------------------


def diloco_round(model: Model, dcfg: DiLoCoConfig, opt, state: PyTree, batches: PyTree,
                 masks: list[PyTree] | None = None) -> tuple[PyTree, dict]:
    """One communication round: H inner steps then outer sync(s).

    ``batches`` leaves: [H, K, B/K, ...]. With streaming (J>1) the round is J
    segments of H/J steps, each followed by a partition-j sync — peak
    bandwidth drops by J while the sync period per partition stays H.
    """
    H, J = dcfg.sync_interval, dcfg.streaming_partitions

    def scan_inner(state, seg_batches):
        def body(st, b):
            st, m = inner_step(model, opt, st, b)
            return st, m["loss"]

        return jax.lax.scan(body, state, seg_batches)

    if J <= 1:
        state, losses = scan_inner(state, batches)
        state, psi = outer_step(dcfg, state)
        return state, {"loss": losses, "psi": psi}

    assert H % J == 0, "streaming requires J | H"
    seg = H // J
    all_losses = []
    for j in range(J):
        seg_batches = jax.tree.map(lambda b: b[j * seg : (j + 1) * seg], batches)
        state, losses = scan_inner(state, seg_batches)
        state, _ = outer_step(dcfg, state, mask=masks[j])
        all_losses.append(losses)
    return state, {"loss": jnp.concatenate(all_losses)}


def make_streaming_masks(state: PyTree, dcfg: DiLoCoConfig) -> list[PyTree] | None:
    if dcfg.streaming_partitions <= 1:
        return None
    return streaming_masks(state["outer_params"], dcfg.streaming_partitions)


# ---------------------------------------------------------------------------
# Data-parallel baseline (K=1, H=1, no outer): for DP AdamW / DP Muon runs
# ---------------------------------------------------------------------------


def dp_init(model: Model, inner_name: str, inner_cfg: OptimizerConfig, rng: jax.Array):
    params = model.init(rng)
    opt = make_inner_optimizer(inner_name, inner_cfg)
    return {"params": params, "opt_state": opt.init(params)}, opt


def dp_step(model: Model, opt, state: PyTree, batch: PyTree) -> tuple[PyTree, dict]:
    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(state["params"], batch)
    new_p, new_s = opt.step(state["params"], grads, state["opt_state"])
    return {"params": new_p, "opt_state": new_s}, {"loss": loss}
