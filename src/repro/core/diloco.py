"""DiLoCo / MuLoCo: the paper's algorithm as a composable JAX module.

Algorithm 1/2 of the paper, faithfully:

  * K workers each run H local steps of the **inner optimizer**
    (AdamW -> DiLoCo, Muon -> MuLoCo) on their own data shard;
  * every H steps, worker deltas Δ_k = θ_outer − θ_k are (optionally
    EF-compressed and) averaged into the pseudogradient Ψ;
  * the **outer** Nesterov-SGD applies Ψ to the outer params, which are then
    broadcast back to all workers.

Worker state is stacked on a leading K axis. On the production mesh this axis
is sharded over `pod`, so the H inner steps incur **zero cross-pod traffic**
and the Ψ-average is the only cross-pod all-reduce — DiLoCo's communication
pattern expressed purely through shardings. On CPU the same code simulates
any K via vmap. Streaming (partitioned) sync and compressed collectives plug
in through :mod:`repro.core.streaming` / :mod:`repro.core.collectives`.

State lives in :class:`repro.engine.TrainState` (a registered pytree), and
execution goes through :class:`repro.engine.TrainEngine`, which compiles
:func:`diloco_round` once as a donated, jitted program — scanned over R
rounds per dispatch by the superstep executor, of which single-round
execution is the degenerate R=1 case. The DP baseline is the degenerate
``dp_config`` (K=1, H=1, no outer) of the same round.

Both optimizers are transform chains (:mod:`repro.optim.transform`): the
inner step is a ``descend``-wrapped chain from :func:`make_optimizer`, and
the whole pseudogradient path (Δ -> compress/EF -> reduce -> outer descent)
is the chain declared by :func:`make_outer` and executed by ``outer_step``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.collectives import (
    measured_sync_bytes,
    reduce_mean,
    segment_sync_update,
)
from repro.core.compression import CompressionConfig, compress, error_feedback
from repro.core.health import HealthConfig, health_init, health_update
from repro.core.streaming import masked_update, streaming_masks
from repro.models.api import Model
from repro.optim import (
    OptimizerConfig,
    chain,
    make_inner_optimizer,
    make_outer_transform,
)

PyTree = Any


@dataclasses.dataclass(frozen=True)
class DiLoCoConfig:
    n_workers: int = 8  # K
    sync_interval: int = 30  # H
    inner_name: str = "muon"  # 'muon' -> MuLoCo, 'adamw' -> DiLoCo
    outer_name: str = "nesterov"  # 'nesterov' (paper) | 'sgd'
    outer_lr: float = 0.7  # eta_out (paper Fig. 22 optima)
    outer_momentum: float = 0.9  # mu
    compression: CompressionConfig = dataclasses.field(default_factory=CompressionConfig)
    streaming_partitions: int = 1  # J (1 = no streaming)
    ns_impl: str = "jnp"
    # Route the outer descent through the fused Pallas outer-update kernel
    # (kernels/outer_update.py): one elementwise VMEM pass for (theta', u').
    outer_kernel: bool = False
    # False -> the degenerate data-parallel config: no outer Nesterov, the
    # synced params are simply the (K-mean of the) worker params. With
    # K=1, H=1 this IS the plain inner optimizer — DP AdamW / DP Muon run
    # through the exact same round function as DiLoCo/MuLoCo.
    outer_enabled: bool = True
    # Elastic execution: allocate a [K] participation mask in the TrainState
    # (all-ones at init; the driver overwrites it per round). A dropped
    # worker (mask 0) freezes in place for the round — no inner steps, no
    # wire packet, EF residual untouched — and the pseudogradient mean runs
    # over the surviving subset. False keeps the legacy state leaf set and
    # the bit-exact dense program.
    elastic: bool = False
    # Delayed/overlapped outer sync: round r computes its pseudogradient
    # Psi_r (communication + EF happen at r) but the outer descent applies
    # Psi_{r-d} from the TrainState's `pending` FIFO — round r+1's inner
    # steps start from params that have not yet seen Psi_r, masking sync
    # latency (SNOO-style staleness). 0 = lockstep (bit-exact legacy path).
    sync_delay: int = 0
    # In-program health sentinel (core/health.py): when enabled the round
    # emits a per-round anomaly-flag metric (non-finite loss/psi, loss spike
    # vs a running EMA carried in the TrainState) that the driver's
    # RecoveryPolicy reacts to. Disabled (default) adds no state leaf and no
    # traced ops — the lowered program is unchanged.
    health: HealthConfig = dataclasses.field(default_factory=HealthConfig)

    @property
    def is_muloco(self) -> bool:
        return self.inner_name == "muon"


def dp_config(inner_name: str, ns_impl: str = "jnp") -> DiLoCoConfig:
    """The DP baseline as a degenerate DiLoCo config (K=1, H=1, no outer)."""
    return DiLoCoConfig(n_workers=1, sync_interval=1, inner_name=inner_name,
                        outer_lr=1.0, outer_momentum=0.0, outer_enabled=False,
                        ns_impl=ns_impl)


def make_optimizer(dcfg: DiLoCoConfig, inner_cfg: OptimizerConfig):
    kw = {"ns_impl": dcfg.ns_impl} if dcfg.inner_name != "adamw" else {}
    return make_inner_optimizer(dcfg.inner_name, inner_cfg, **kw)


# ---------------------------------------------------------------------------
# The outer optimizer: a declared pseudogradient chain
# ---------------------------------------------------------------------------


class OuterOptimizer:
    """The pseudogradient path Δ -> compress/EF -> reduce -> outer descent as
    ONE declared transform chain (``self.tx``), replacing the inline branches
    the pre-transform ``outer_step`` hand-wired.

    Chain state is the stage tuple ``(ef_residuals | (), (), outer_opt)``;
    the TrainState keeps storing the EF residuals and the outer-transform
    state in its ``ef`` / ``outer_opt`` fields (they shard differently:
    K-stacked vs ZeRO over pods), and this wrapper packs/unpacks them around
    the chain. ``step`` also owns the streaming-mask merge semantics, which
    are stage-specific: candidate params and outer momentum merge under the
    partition mask, untouched partitions keep their EF residuals.
    """

    def __init__(self, dcfg: DiLoCoConfig, state_dtype="float32"):
        ccfg = dcfg.compression
        self.dcfg = dcfg
        self.state_dtype = jnp.dtype(state_dtype)
        self.has_ef = bool(ccfg.error_feedback and ccfg.kind != "none")
        self.has_wire = ccfg.kind != "none"
        self.worker_stage = error_feedback(ccfg) if self.has_ef else compress(ccfg)
        self.terminal = make_outer_transform(
            dcfg.outer_name, dcfg.outer_lr, dcfg.outer_momentum,
            state_dtype=self.state_dtype, kernel=dcfg.outer_kernel)
        self.tx = chain(self.worker_stage, reduce_mean(ccfg), self.terminal)

    # -- state construction --------------------------------------------------

    def init_opt(self, params: PyTree) -> PyTree:
        """Outer-transform state (no K axis; ZeRO-sharded on the mesh)."""
        return self.terminal.init(params)

    def init_ef(self, params: PyTree, n_workers: int) -> PyTree | None:
        """K-stacked EF residuals, or None when the config never uses them.

        Matches the legacy allocation rule: residuals exist whenever
        ``error_feedback=True`` (even with ``kind='none'``, where the chain
        skips the EF stage)."""
        if not self.dcfg.compression.error_feedback:
            return None
        template = jax.tree.map(
            lambda p: jnp.zeros((n_workers, *p.shape), self.state_dtype), params)
        return error_feedback(self.dcfg.compression).init(template)

    # -- the sync ------------------------------------------------------------

    def reduce(self, params: PyTree, deltas: PyTree, ef: PyTree | None,
               mask: PyTree | None = None,
               participation: jax.Array | None = None):
        """The communication half of the sync: worker stage (compress/EF) +
        the pseudogradient all-reduce, NO outer descent. Returns
        ``(psi, new_ef)``.

        A streaming segment (``mask`` present) with wire compression routes
        through :func:`repro.core.collectives.segment_sync_update` instead
        of the dense stages: the concrete mask subsets the wire rows, so the
        simulated buffers themselves shrink to the segment's share. Masks
        are closure constants of the jitted round — a traced mask falls back
        to the full-size masked encode.

        An elastic ``participation`` mask ([K] {0,1}, traced) restricts the
        reduce to surviving workers (threaded into
        :func:`repro.core.collectives.reduce_mean`) and **freezes** dropped
        workers' EF residuals: their packets were never sent, so their
        residuals must come back bit-identical, not EF-decayed.
        """
        ccfg = self.dcfg.compression
        concrete_mask = mask is not None and not any(
            isinstance(m, jax.core.Tracer) for m in jax.tree.leaves(mask))
        if concrete_mask and self.has_wire:
            psi, seg_ef = segment_sync_update(
                deltas, ef if self.has_ef else None, mask, ccfg,
                participation=participation)
            new_ef = seg_ef if self.has_ef else ef
        else:
            sub = chain(self.worker_stage, reduce_mean(ccfg, participation))
            psi, sub_state = sub.update(
                deltas, (ef if self.has_ef else (), ()), params)
            new_ef = sub_state[0] if self.has_ef else ef
        if participation is not None and self.has_ef and ef is not None:
            pk = participation.astype(jnp.float32)
            new_ef = jax.tree.map(
                lambda ne, oe: jnp.where(
                    pk.reshape((pk.shape[0],) + (1,) * (ne.ndim - 1)) > 0,
                    ne, oe.astype(ne.dtype)),
                new_ef, ef)
        return psi, new_ef

    def descend(self, params: PyTree, psi: PyTree, opt_state: PyTree):
        """The terminal half: outer transform update + parameter descent on
        an already-reduced pseudogradient. Returns ``(new_params, new_opt)``.
        Split from :meth:`reduce` so the delayed-sync mode can apply a
        *stale* psi while the fresh one enters the pending FIFO."""
        psi, opt_after = self.terminal.update(psi, opt_state, params)
        return self.terminal.apply(params, psi, opt_after)

    def step(self, params: PyTree, deltas: PyTree, opt_state: PyTree,
             ef: PyTree | None, mask: PyTree | None = None,
             participation: jax.Array | None = None):
        """Run the full chain on (masked) deltas; returns
        ``(new_params, new_opt_state, new_ef, psi)``. Exactly
        :meth:`reduce` followed by :meth:`descend` — the same op sequence
        the one-shot ``self.tx`` chain produced — plus the streaming-mask
        merge semantics, which are stage-specific: candidate params and
        outer momentum merge under the partition mask, untouched partitions
        keep their EF residuals.
        """
        psi, new_ef = self.reduce(params, deltas, ef, mask=mask,
                                  participation=participation)
        cand_params, new_opt = self.descend(params, psi, opt_state)
        if mask is None:
            return cand_params, new_opt, new_ef, psi
        new_params = masked_update(mask, cand_params, params)
        new_opt = self.terminal.mask_state(mask, new_opt, opt_state)
        if self.has_ef:  # untouched partitions keep their residuals
            new_ef = jax.tree.map(
                lambda m, ne, oe: jnp.where((m[None] if m.ndim else m) > 0, ne, oe),
                mask, new_ef, ef)
        return new_params, new_opt, new_ef, psi


def make_outer(dcfg: DiLoCoConfig, state_dtype="float32") -> OuterOptimizer:
    """Build the declared pseudogradient chain for a DiLoCo config."""
    return OuterOptimizer(dcfg, state_dtype=state_dtype)


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------


def diloco_init(model: Model, dcfg: DiLoCoConfig, inner_cfg: OptimizerConfig, rng: jax.Array) -> PyTree:
    # imported lazily: repro.engine builds on repro.core, not the reverse
    from repro.engine.state import TrainState

    if dcfg.sync_delay:
        if not dcfg.outer_enabled:
            raise ValueError("sync_delay requires the outer optimizer "
                             "(outer_enabled=False has no pseudogradient to delay)")
        if dcfg.streaming_partitions > 1:
            raise ValueError("sync_delay cannot be combined with streaming "
                             "(J>1) segment syncs")
    params = model.init(rng)
    K = dcfg.n_workers
    worker_params = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (K, *p.shape)), params)
    opt = make_optimizer(dcfg, inner_cfg)
    inner_state = jax.vmap(opt.init)(worker_params)
    outer = make_outer(dcfg, state_dtype=inner_cfg.state_dtype)
    # the pending FIFO starts as zeros: the first sync_delay rounds apply a
    # zero pseudogradient (the outer params hold still while the pipeline
    # fills), exactly the cold-start a delayed production sync would see
    pending = (jax.tree.map(
        lambda p: jnp.zeros((dcfg.sync_delay, *p.shape), jnp.float32), params)
        if dcfg.sync_delay else None)
    return TrainState(
        outer_params=params,
        outer_opt=outer.init_opt(params),
        worker_params=worker_params,
        inner_state=inner_state,
        round=jnp.zeros((), jnp.int32),
        ef=outer.init_ef(params, K),
        participation=(jnp.ones((K,), jnp.float32) if dcfg.elastic else None),
        pending=pending,
        health=health_init(dcfg.health),
    )


def _updated(state: PyTree, **kw) -> PyTree:
    """Functional update working on both TrainState and legacy dict states."""
    if hasattr(state, "replace"):
        return state.replace(**kw)
    new = dict(state)
    new.update(kw)
    return new


# ---------------------------------------------------------------------------
# Inner step (runs every step; no cross-worker communication)
# ---------------------------------------------------------------------------


def inner_step(model: Model, opt, state: PyTree, batch: PyTree,
               spmd_axis: str | None = None,
               participation: jax.Array | None = None) -> tuple[PyTree, dict]:
    """One local optimizer step on every worker. batch leaves: [K, B/K, ...].

    ``spmd_axis='pod'`` tells GSPMD the vmapped worker axis lives on the pod
    mesh axis, so activation sharding constraints inside the model compose
    with the worker dimension on the production mesh.

    An elastic ``participation`` mask ([K] {0,1}) freezes dropped workers in
    place: their params and inner-optimizer state come back bit-identical
    (``where`` on the mask) and the reported loss is the mean over the
    surviving workers only. The all-ones mask selects every new value
    elementwise, so it is bitwise-equal to the maskless program."""

    def one(params_k, inner_k, batch_k):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params_k, batch_k)
        new_p, new_s = opt.step(params_k, grads, inner_k)
        return new_p, new_s, loss

    new_wp, new_is, losses = jax.vmap(one, spmd_axis_name=spmd_axis)(
        state["worker_params"], state["inner_state"], batch)
    if participation is None:
        loss = jnp.mean(losses)
    else:
        pk = participation.astype(jnp.float32)

        def freeze(new, old):
            pb = pk.reshape((pk.shape[0],) + (1,) * (new.ndim - 1))
            return jnp.where(pb > 0, new, old)

        new_wp = jax.tree.map(freeze, new_wp, state["worker_params"])
        new_is = jax.tree.map(freeze, new_is, state["inner_state"])
        # reciprocal form: bitwise == jnp.mean for the all-ones mask
        loss = jnp.sum(pk * losses) * (1.0 / jnp.maximum(jnp.sum(pk), 1.0))
    new_state = _updated(state, worker_params=new_wp, inner_state=new_is)
    return new_state, {"loss": loss, "loss_per_worker": losses}


# ---------------------------------------------------------------------------
# Outer step (the only cross-worker communication)
# ---------------------------------------------------------------------------


def compute_deltas(state: PyTree) -> PyTree:
    """Δ_k = θ_outer − θ_k, stacked [K, ...] (paper Alg. 1 line 9)."""
    return jax.tree.map(
        lambda o, w: o.astype(jnp.float32)[None] - w.astype(jnp.float32),
        state["outer_params"], state["worker_params"],
    )


_FROM_STATE = object()  # sentinel: outer_step reads participation off the state


def outer_step(dcfg: DiLoCoConfig, state: PyTree, mask: PyTree | None = None,
               outer: OuterOptimizer | None = None,
               participation: jax.Array | None = _FROM_STATE) -> tuple[PyTree, PyTree]:
    """Communicate + outer update (+ worker reset). Returns (state, Ψ).

    The pseudogradient path Δ -> compress/EF -> reduce -> outer descent runs
    through the declared :class:`OuterOptimizer` chain (built from ``dcfg``
    when not supplied — the engine builds it once and threads it through).

    Elastic execution reads the [K] participation mask from the TrainState
    (pass ``participation=None`` explicitly to force the dense program — the
    all-ones branch of :func:`diloco_round`'s runtime cond does this so the
    full-participation round is the *literal* maskless computation, bitwise):
    dropped workers' deltas are excluded from the reduce, their EF residuals
    come back frozen, and every worker — dropped ones included — resets to
    the new outer params (rejoin IS the broadcast; a dropped worker did no
    inner steps, so overwriting its frozen replica is unobservable).

    With ``dcfg.sync_delay = d > 0`` the fresh pseudogradient Ψ_r enters the
    ``pending`` FIFO while the descent applies ``pending[0]`` = Ψ_{r-d}:
    round r+1 starts from params that have not yet absorbed Ψ_r, which is
    what lets a real deployment overlap the sync with the next round's
    compute. Communication, EF accumulation, and byte accounting all happen
    at round r — only the *application* is late.

    With ``dcfg.outer_enabled=False`` (the DP degenerate config) the synced
    params are simply the K-mean of the worker params: no outer transform, no
    compression, no worker reset — at K=1 this is exactly the plain inner
    optimizer, through the same code path as DiLoCo/MuLoCo.
    """
    from repro.core.collectives import participation_mean

    if participation is _FROM_STATE:
        participation = state.get("participation")
    deltas = compute_deltas(state)
    if not dcfg.outer_enabled:
        if mask is not None:
            raise ValueError(
                "streaming (partitioned) sync requires the outer optimizer; "
                "outer_enabled=False cannot be combined with streaming_partitions > 1")
        if participation is None or dcfg.n_workers == 1:
            # legacy dense program (a K=1 elastic mask is always all-ones)
            psi = jax.tree.map(lambda d: jnp.mean(d, axis=0), deltas)
            new_outer = jax.tree.map(
                lambda o, w: jnp.mean(w.astype(jnp.float32), axis=0).astype(o.dtype)
                if w.shape[0] > 1 else w[0],
                state["outer_params"], state["worker_params"],
            )
        else:
            psi = jax.tree.map(
                lambda d: participation_mean(d, participation), deltas)
            new_outer = jax.tree.map(
                lambda o, w: participation_mean(
                    w.astype(jnp.float32), participation).astype(o.dtype),
                state["outer_params"], state["worker_params"],
            )
        # broadcast the averaged params back so workers stay synced (at K=1
        # this is the identity; at K>1 it is every-H parameter averaging —
        # without it the replicas would silently drift apart forever)
        new_workers = jax.tree.map(
            lambda o, w: jnp.broadcast_to(o[None].astype(w.dtype), w.shape),
            new_outer, state["worker_params"],
        )
        return _updated(state, outer_params=new_outer, worker_params=new_workers,
                        round=state["round"] + 1), psi
    if mask is not None:
        deltas = jax.tree.map(lambda m, d: m[None] * d if m.ndim else m * d, mask, deltas)

    outer = outer or make_outer(dcfg)
    if dcfg.sync_delay:
        if mask is not None:
            raise ValueError("sync_delay cannot be combined with streaming "
                             "(J>1) segment syncs")
        pending = state.get("pending")
        if pending is None:
            raise ValueError("sync_delay > 0 needs the pending FIFO in the "
                             "TrainState; build it with diloco_init on a "
                             "config with the same sync_delay")
        psi, new_ef = outer.reduce(state["outer_params"], deltas,
                                   state.get("ef"),
                                   participation=participation)
        stale_psi = jax.tree.map(lambda q: q[0], pending)
        new_outer, new_opt = outer.descend(state["outer_params"], stale_psi,
                                           state["outer_opt"])
        new_pending = jax.tree.map(
            lambda q, pn: jnp.concatenate(
                [q[1:], pn[None].astype(q.dtype)], axis=0),
            pending, psi)
    else:
        new_pending = None
        new_outer, new_opt, new_ef, psi = outer.step(
            state["outer_params"], deltas, state["outer_opt"], state.get("ef"),
            mask=mask, participation=participation)

    # broadcast synced params back to workers (masked portions only)
    def reset(o, w, m=None):
        ob = jnp.broadcast_to(o[None].astype(w.dtype), w.shape)
        if m is None:
            return ob
        mm = m[None] if m.ndim else m
        return (mm * ob.astype(jnp.float32) + (1 - mm) * w.astype(jnp.float32)).astype(w.dtype)

    if mask is None:
        new_workers = jax.tree.map(reset, new_outer, state["worker_params"])
    else:
        new_workers = jax.tree.map(lambda o, w, m: reset(o, w, m), new_outer, state["worker_params"], mask)

    updates: dict = dict(outer_params=new_outer, outer_opt=new_opt,
                         worker_params=new_workers)
    if new_ef is not None:
        updates["ef"] = new_ef
    if new_pending is not None:
        updates["pending"] = new_pending
    updates["round"] = state["round"] + 1
    return _updated(state, **updates), psi


# ---------------------------------------------------------------------------
# Full round(s): H inner steps + sync (jit-able end to end)
# ---------------------------------------------------------------------------


def diloco_round(model: Model, dcfg: DiLoCoConfig, opt, state: PyTree, batches: PyTree,
                 masks: list[PyTree] | None = None,
                 spmd_axis: str | None = None,
                 outer: OuterOptimizer | None = None) -> tuple[PyTree, dict]:
    """One communication round: H inner steps then outer sync(s).

    This is THE round function: ``lax.scan`` over the H inner steps with the
    outer sync (and, for streaming, the J per-segment partition syncs —
    statically unrolled, since each segment carries a different mask) folded
    into the same traced program. The sync itself is not hand-wired here: it
    is the declared pseudogradient transform chain Δ -> compress/EF ->
    reduce -> outer descent built by :func:`make_outer` and threaded through
    ``outer_step``. :class:`repro.engine.TrainEngine` wraps this function in
    the superstep executor (``lax.scan`` over R rounds per dispatch,
    :mod:`repro.engine.superstep`), compiles it once, donated, and every
    training path (train / dryrun / bench / examples) executes it.

    ``batches`` leaves: [H, K, B/K, ...]. With streaming (J>1) the round is J
    segments of H/J steps, each followed by a partition-j sync — peak
    bandwidth drops by J while the sync period per partition stays H.

    Returns ``(state, {"loss": f32[H], "psi": pseudogradient_tree,
    "comm_bytes": f32[], "active_workers": f32[], "staleness": f32[]})`` for
    every J; with J>1 the ``psi`` leaves are the mask-combined per-segment
    pseudogradients (each parameter's entry comes from the segment that
    synced it), so the signature is identical to the J==1 path.
    ``comm_bytes`` is the round's measured per-worker wire traffic — read
    off the actual wire buffer shapes/dtypes the sync(s) move
    (:func:`repro.core.collectives.measured_sync_bytes`), summed over the J
    segment syncs (each segment ships its partition's share). On an elastic
    round the dense total is scaled by the surviving-worker fraction
    ``sum(p)/K`` — dropped workers' packets are never encoded, so they are
    not charged. The metric travels as f32 (x64 is disabled), so above
    ~16.7 MB/round it carries ~7 significant digits; exact integers come
    from calling ``measured_sync_bytes`` directly. ``active_workers`` is
    the round's surviving-worker count (== K on non-elastic rounds) and
    ``staleness`` the config's ``sync_delay``, threaded out so the driver
    can log them per round.
    """
    H, J = dcfg.sync_interval, dcfg.streaming_partitions
    participation = state.get("participation")
    if dcfg.sync_delay and J > 1:
        raise ValueError("sync_delay cannot be combined with streaming "
                         "(J>1) segment syncs")

    def sync_bytes(mask=None) -> int:
        return measured_sync_bytes(state["outer_params"], dcfg.compression,
                                   dcfg.n_workers, mask=mask,
                                   outer_enabled=dcfg.outer_enabled)

    def comm_metric(dense_bytes: int) -> jax.Array:
        """Dense per-worker wire bytes, fraction-scaled on elastic rounds.

        The ``c * (sum(p)/K)`` op order matters: ``sum(p)/K`` is exactly 1.0
        for the all-ones mask at any K, so the dense program's
        ``asarray(bytes)`` value comes back bit-identical."""
        c = jnp.asarray(dense_bytes, jnp.float32)
        if participation is None:
            return c
        p = participation.astype(jnp.float32)
        return c * (jnp.sum(p) / jnp.float32(dcfg.n_workers))

    active = (jnp.sum(participation.astype(jnp.float32))
              if participation is not None
              else jnp.asarray(float(dcfg.n_workers), jnp.float32))
    staleness = jnp.asarray(float(dcfg.sync_delay), jnp.float32)

    def scan_inner(state, seg_batches, part):
        # carry only what the inner steps mutate: outer params/opt, EF
        # residuals and the round counter are loop-invariant and stay out of
        # the while-loop state.
        def body(carry, b):
            sub = {"worker_params": carry[0], "inner_state": carry[1]}
            sub, m = inner_step(model, opt, sub, b, spmd_axis=spmd_axis,
                                participation=part)
            return (sub["worker_params"], sub["inner_state"]), m["loss"]

        (wp, ins), losses = jax.lax.scan(
            body, (state["worker_params"], state["inner_state"]), seg_batches)
        return _updated(state, worker_params=wp, inner_state=ins), losses

    if J <= 1:
        comm = sync_bytes()

        def run_round(state, part):
            state, losses = scan_inner(state, batches, part)
            state, psi = outer_step(dcfg, state, outer=outer,
                                    participation=part)
            return state, losses, psi

        def finish(state, losses, psi):
            # health sentinel rides AFTER the participation cond so the flag
            # sees the round's final losses/psi whichever branch produced
            # them; with no health leaf this is the identity (zero ops)
            health = state.get("health")
            info = {"loss": losses, "psi": psi,
                    "comm_bytes": comm_metric(comm),
                    "active_workers": active, "staleness": staleness}
            if health is not None:
                new_health, flag = health_update(dcfg.health, health, losses, psi)
                state = _updated(state, health=new_health)
                info["health"] = flag
            return state, info

        if participation is None:
            state, losses, psi = run_round(state, None)
        else:
            # Runtime two-way dispatch: the full-participation round executes
            # the LITERAL dense program (same ops, same fusions — the masked
            # program's extra selects perturb XLA fusion by 1 ulp even under
            # an all-ones mask), so elastic configs stay bitwise-equal to the
            # maskless path whenever nobody dropped. Only genuinely degraded
            # rounds pay for the masked computation.
            state, losses, psi = jax.lax.cond(
                jnp.all(participation > 0),
                lambda st: run_round(st, None),
                lambda st: run_round(st, participation),
                state)
        return finish(state, losses, psi)

    if H % J:
        raise ValueError(
            f"streaming requires the partition count to divide the sync "
            f"interval: J={J} does not divide H={H}")
    if masks is None:
        raise ValueError(
            "streaming (J>1) requires partition masks; build them with "
            "make_streaming_masks(state, dcfg)")
    seg = H // J
    comm = sum(sync_bytes(mask=masks[j]) for j in range(J))

    def run_segments(state, part):
        all_losses = []
        psi_acc = None
        for j in range(J):
            seg_batches = jax.tree.map(lambda b: b[j * seg : (j + 1) * seg], batches)
            state, losses = scan_inner(state, seg_batches, part)
            state, psi_j = outer_step(dcfg, state, mask=masks[j], outer=outer,
                                      participation=part)
            # psi leaves are un-stacked (no K axis): the masks broadcast directly
            masked_j = jax.tree.map(lambda m, p: m * p, masks[j], psi_j)
            psi_acc = masked_j if psi_acc is None else jax.tree.map(jnp.add, psi_acc, masked_j)
            all_losses.append(losses)
        return state, jnp.concatenate(all_losses), psi_acc

    if participation is None:
        state, losses, psi = run_segments(state, None)
    else:
        # same two-way dispatch as J==1: all-ones -> the literal dense
        # J-segment program, any drop -> the masked program
        state, losses, psi = jax.lax.cond(
            jnp.all(participation > 0),
            lambda st: run_segments(st, None),
            lambda st: run_segments(st, participation),
            state)
    info = {"loss": losses, "psi": psi, "comm_bytes": comm_metric(comm),
            "active_workers": active, "staleness": staleness}
    health = state.get("health")
    if health is not None:  # same post-cond sentinel as the J==1 path
        new_health, flag = health_update(dcfg.health, health, losses, psi)
        state = _updated(state, health=new_health)
        info["health"] = flag
    return state, info


def make_streaming_masks(state: PyTree, dcfg: DiLoCoConfig) -> list[PyTree] | None:
    if dcfg.streaming_partitions <= 1:
        return None
    return streaming_masks(state["outer_params"], dcfg.streaming_partitions)


# ---------------------------------------------------------------------------
# Data-parallel baseline: the degenerate (K=1, H=1, no-outer) engine config.
# dp_init/dp_step are thin adapters over the same inner_step used by DiLoCo —
# one code path for DP AdamW / DP Muon and MuLoCo/DiLoCo alike.
# ---------------------------------------------------------------------------


def dp_init(model: Model, inner_name: str, inner_cfg: OptimizerConfig, rng: jax.Array):
    params = model.init(rng)
    opt = make_inner_optimizer(inner_name, inner_cfg)
    return {"params": params, "opt_state": opt.init(params)}, opt


def dp_step(model: Model, opt, state: PyTree, batch: PyTree) -> tuple[PyTree, dict]:
    """One DP step == one DiLoCo inner step at K=1 (shared implementation)."""
    stacked = {
        "worker_params": jax.tree.map(lambda p: p[None], state["params"]),
        "inner_state": jax.tree.map(lambda s: s[None], state["opt_state"]),
    }
    new, metrics = inner_step(model, opt, stacked, jax.tree.map(lambda x: x[None], batch))
    return {
        "params": jax.tree.map(lambda p: p[0], new["worker_params"]),
        "opt_state": jax.tree.map(lambda s: s[0], new["inner_state"]),
    }, {"loss": metrics["loss"]}
