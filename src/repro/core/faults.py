"""Fault injection: worker churn (elastic DiLoCo) and whole-run crash chaos.

Production runs at the paper's K=16 scale lose workers — preemptions,
hardware faults, stragglers cut off at the round barrier. Elastic DiLoCo
models that as a per-round **participation mask**: a float32 {0,1} vector of
length K carried in ``TrainState.participation`` and consumed by
:func:`repro.core.diloco.diloco_round`. A dropped worker freezes in place
(no inner steps, no wire packet, EF residual untouched) and its delta is
excluded from the pseudogradient mean; on rejoin it resets to the current
outer params exactly like every other worker at the sync, so rejoining IS
the normal DiLoCo broadcast.

This module is the host side: it turns a fault specification — a scripted
drop schedule and/or an i.i.d. drop probability — into the ``[R, K]`` mask
stacks the superstep scans over. Masks are a pure function of
``(seed, absolute round)``, so any rounds-per-dispatch chunking of the same
run sees identical masks (the same property that makes R a pure scheduling
knob for batches).

Beyond worker churn, :class:`CrashPlan` injects *driver-level* faults so the
crash-safety subsystem (checksummed checkpoints, the health sentinel, the
recovery policy, preemption handling) is provable end-to-end: poison a
chosen round's state with a NaN, corrupt a chosen round's labels into a loss
spike, SIGKILL the process at a chosen round, and (for tests) truncate or
bit-flip a checkpoint file on disk.
"""
from __future__ import annotations

import dataclasses
import os
import signal

import numpy as np


def parse_drop_schedule(spec: str) -> dict[int, tuple[int, ...]]:
    """Parse ``'round:worker[;round:worker...]'`` into {round: (workers,)}.

    Example: ``'1:2;1:3;4:0'`` drops workers 2 and 3 in round 1 and worker 0
    in round 4 (rounds and workers are 0-indexed; a worker is dropped only
    for the rounds listed — it rejoins automatically afterwards). Both ``;``
    and ``,`` separate entries.
    """
    sched: dict[int, list[int]] = {}
    for entry in spec.replace(",", ";").split(";"):
        entry = entry.strip()
        if not entry:
            continue
        try:
            r_s, w_s = entry.split(":")
            r, w = int(r_s), int(w_s)
        except ValueError as e:
            raise ValueError(
                f"bad --drop-schedule entry {entry!r}: expected 'round:worker'") from e
        if r < 0 or w < 0:
            raise ValueError(f"--drop-schedule entry {entry!r}: negative index")
        sched.setdefault(r, []).append(w)
    return {r: tuple(sorted(set(ws))) for r, ws in sched.items()}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Host-side participation-mask generator for an elastic run.

    ``drop_prob`` drops each worker independently per round; ``schedule``
    (see :func:`parse_drop_schedule`) forces specific (round, worker) drops
    on top. At least one worker always survives: if a round would drop
    everyone, the worker with the largest random draw — the last one any
    drop rate would evict — is kept (the same tie-break as
    :class:`repro.core.wallclock.StragglerModel`, where it makes round
    times monotone in the drop rate).
    """

    n_workers: int
    drop_prob: float = 0.0
    schedule: dict[int, tuple[int, ...]] | None = None
    seed: int = 0

    def mask_for_round(self, r: int) -> np.ndarray:
        """[K] float32 {0,1} participation for absolute round ``r``."""
        K = self.n_workers
        # per-(seed, round) generator: masks are chunking-invariant
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, r]))
        u = rng.random(K)
        active = np.ones(K, bool) if self.drop_prob <= 0 else (u >= self.drop_prob)
        for w in (self.schedule or {}).get(r, ()):
            if w < K:
                active[w] = False
        if not active.any():
            active[int(np.argmax(u))] = True
        return active.astype(np.float32)

    def masks(self, r0: int, n: int) -> np.ndarray:
        """[n, K] float32 masks for rounds ``r0 .. r0+n-1``."""
        return np.stack([self.mask_for_round(r0 + i) for i in range(n)])

    @property
    def is_trivial(self) -> bool:
        return self.drop_prob <= 0 and not self.schedule


# ---------------------------------------------------------------------------
# Driver-level crash chaos: NaN / spike / SIGKILL injection
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CrashPlan:
    """Scripted crash/corruption events for proving the recovery paths.

    * ``nan_round`` — poison one worker-parameter element with NaN at the
      dispatch that STARTS at this round (``apply`` is the driver's
      ``inject`` hook; the caller pins ``rounds_per_dispatch=1`` while a NaN
      injection is armed so the poison lands exactly at the target round).
      The NaN then flows through the real forward/backward/psi path — this
      is a state-poisoning fault, because the token batches are integers and
      cannot carry a NaN themselves.
    * ``spike_round`` — overwrite one worker-parameter element with a large
      *finite* value (``spike_value``) at that round's dispatch: a silent
      data corruption (the exponent bit-flip kind) that sends the loss
      through the roof without ever going non-finite, so it exercises the
      EMA spike detector rather than the isfinite flags.
    * ``kill_round`` — ``SIGKILL`` our own process the moment this round's
      metrics drain (:meth:`maybe_kill` from the caller's ``on_round``): no
      handlers, no cleanup, the honest crash the bitwise-resume invariant is
      tested against.
    """

    nan_round: int | None = None
    spike_round: int | None = None
    kill_round: int | None = None
    spike_value: float = 100.0  # the corrupted element's finite value

    @property
    def is_trivial(self) -> bool:
        return (self.nan_round is None and self.spike_round is None
                and self.kill_round is None)

    @property
    def needs_single_round_dispatch(self) -> bool:
        """State poisoning edits the carry at a dispatch boundary; R must be
        1 so the boundary IS the target round."""
        return self.nan_round is not None or self.spike_round is not None

    def _poison(self, state, value):
        """Set one element of worker 0's first parameter leaf."""
        import jax

        leaves = jax.tree.leaves(state["worker_params"])
        poisoned = leaves[0].at[(0,) * leaves[0].ndim].set(value)
        wp = jax.tree.unflatten(
            jax.tree.structure(state["worker_params"]),
            [poisoned] + leaves[1:])
        return (state.replace(worker_params=wp) if hasattr(state, "replace")
                else {**state, "worker_params": wp})

    def apply(self, r0: int, n: int, batches, state):
        """The driver ``inject`` hook: corrupt the state (and/or the
        span-stacked batches, leaves [n, H, K, B, ...]) for rounds
        r0..r0+n-1. Returns ``(batches, state)`` unchanged when no event
        lands here."""
        import jax.numpy as jnp

        if self.nan_round is not None and r0 == self.nan_round:
            state = self._poison(state, jnp.nan)
        if self.spike_round is not None and r0 == self.spike_round:
            state = self._poison(state, self.spike_value)
        return batches, state

    def maybe_kill(self, round: int) -> None:
        """SIGKILL self when ``round``'s metrics have drained (call from
        ``on_round`` AFTER persisting the round's row, so the dead process
        leaves exactly the on-disk trail a real crash would)."""
        if self.kill_round is not None and round == self.kill_round:
            os.kill(os.getpid(), signal.SIGKILL)


# -- on-disk corruption helpers (tests exercise the loader's fallback) ------


def truncate_file(path: str, keep_bytes: int = 0) -> None:
    """Truncate a file in place — a torn write / partial flush."""
    with open(path, "r+b") as f:
        f.truncate(keep_bytes)


def corrupt_file(path: str, offset: int = -64, flip: int = 0xFF) -> None:
    """Flip the bits of one byte in place — silent on-disk corruption that
    only a checksum can catch (the zip structure usually stays readable)."""
    size = os.path.getsize(path)
    pos = offset % size
    with open(path, "r+b") as f:
        f.seek(pos)
        b = f.read(1)
        f.seek(pos)
        f.write(bytes([b[0] ^ flip]))
