"""Fault injection: per-round worker participation for elastic DiLoCo.

Production runs at the paper's K=16 scale lose workers — preemptions,
hardware faults, stragglers cut off at the round barrier. Elastic DiLoCo
models that as a per-round **participation mask**: a float32 {0,1} vector of
length K carried in ``TrainState.participation`` and consumed by
:func:`repro.core.diloco.diloco_round`. A dropped worker freezes in place
(no inner steps, no wire packet, EF residual untouched) and its delta is
excluded from the pseudogradient mean; on rejoin it resets to the current
outer params exactly like every other worker at the sync, so rejoining IS
the normal DiLoCo broadcast.

This module is the host side: it turns a fault specification — a scripted
drop schedule and/or an i.i.d. drop probability — into the ``[R, K]`` mask
stacks the superstep scans over. Masks are a pure function of
``(seed, absolute round)``, so any rounds-per-dispatch chunking of the same
run sees identical masks (the same property that makes R a pure scheduling
knob for batches).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def parse_drop_schedule(spec: str) -> dict[int, tuple[int, ...]]:
    """Parse ``'round:worker[;round:worker...]'`` into {round: (workers,)}.

    Example: ``'1:2;1:3;4:0'`` drops workers 2 and 3 in round 1 and worker 0
    in round 4 (rounds and workers are 0-indexed; a worker is dropped only
    for the rounds listed — it rejoins automatically afterwards). Both ``;``
    and ``,`` separate entries.
    """
    sched: dict[int, list[int]] = {}
    for entry in spec.replace(",", ";").split(";"):
        entry = entry.strip()
        if not entry:
            continue
        try:
            r_s, w_s = entry.split(":")
            r, w = int(r_s), int(w_s)
        except ValueError as e:
            raise ValueError(
                f"bad --drop-schedule entry {entry!r}: expected 'round:worker'") from e
        if r < 0 or w < 0:
            raise ValueError(f"--drop-schedule entry {entry!r}: negative index")
        sched.setdefault(r, []).append(w)
    return {r: tuple(sorted(set(ws))) for r, ws in sched.items()}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Host-side participation-mask generator for an elastic run.

    ``drop_prob`` drops each worker independently per round; ``schedule``
    (see :func:`parse_drop_schedule`) forces specific (round, worker) drops
    on top. At least one worker always survives: if a round would drop
    everyone, the worker with the largest random draw — the last one any
    drop rate would evict — is kept (the same tie-break as
    :class:`repro.core.wallclock.StragglerModel`, where it makes round
    times monotone in the drop rate).
    """

    n_workers: int
    drop_prob: float = 0.0
    schedule: dict[int, tuple[int, ...]] | None = None
    seed: int = 0

    def mask_for_round(self, r: int) -> np.ndarray:
        """[K] float32 {0,1} participation for absolute round ``r``."""
        K = self.n_workers
        # per-(seed, round) generator: masks are chunking-invariant
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, r]))
        u = rng.random(K)
        active = np.ones(K, bool) if self.drop_prob <= 0 else (u >= self.drop_prob)
        for w in (self.schedule or {}).get(r, ()):
            if w < K:
                active[w] = False
        if not active.any():
            active[int(np.argmax(u))] = True
        return active.astype(np.float32)

    def masks(self, r0: int, n: int) -> np.ndarray:
        """[n, K] float32 masks for rounds ``r0 .. r0+n-1``."""
        return np.stack([self.mask_for_round(r0 + i) for i in range(n)])

    @property
    def is_trivial(self) -> bool:
        return self.drop_prob <= 0 and not self.schedule
