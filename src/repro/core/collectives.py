"""Communication collectives for the pseudogradient all-reduce.

The paper (§2 "Collectives for compressed communication", App. C.1) models an
**all-to-all reduce-scatter followed by a ring all-gather**: each worker's
quantized pseudogradient shard is dequantized and reduced *once* in high
precision on its owner device, re-quantized, and all-gathered — exactly two
quantize/dequantize ops total, avoiding the per-hop error accumulation of a
ring all-reduce. Top-k instead uses an all-gather + local reduce (one
compression).

The reduce here is **wire-format-faithful**: it consumes the real wire
buffers the worker stage emitted (:mod:`repro.core.wire` — bit-packed codes
+ row metadata, or (index, value) pairs), decodes them (D1), reduces in
fp32, and for the quantized a2a_rs_ag collective re-encodes/decodes the
reduced shard (Q2/D2) through another wire buffer. Workers live on a stacked
leading K axis (sharded over the `pod` mesh axis in production), so ``mean
over axis 0`` lowers to the cross-pod all-reduce.

Byte accounting comes in two flavors:

* :func:`measured_sync_bytes` — **measured**: read off the actual wire
  buffer shapes/dtypes (codes + metadata + indices, packing padding and
  all) via ``jax.eval_shape`` on the real encode path; this is what the
  engine threads into the per-round ``comm_bytes`` metric;
* :func:`collective_bytes_tree` — the original closed-form **model**
  (Tab. 10 / Fig. 16), kept for the wallclock estimates where no concrete
  parameter tree exists.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import CompressionConfig
from repro.core.wire import (
    decode_leaf,
    encode_leaf,
    is_wire,
    wire_tree_bytes,
)

PyTree = Any


def participation_mean(vals: jax.Array, participation: jax.Array | None) -> jax.Array:
    """Mean over the leading K axis restricted to participating workers.

    ``participation`` is a [K] float32 {0,1} mask (``None`` = everyone).
    Computed as ``sum(p * vals) * (1 / max(sum(p), 1))`` — the reciprocal
    form is what makes the all-ones mask **bitwise identical** to
    ``jnp.mean`` at every K (``jnp.mean`` multiplies by the reciprocal of
    the count; a plain division differs in the last ulp whenever 1/K is
    inexact, e.g. K=3).
    """
    if participation is None:
        return jnp.mean(vals, axis=0)
    p = participation.astype(jnp.float32)
    pb = p.reshape((p.shape[0],) + (1,) * (vals.ndim - 1))
    return jnp.sum(pb * vals, axis=0) * (1.0 / jnp.maximum(jnp.sum(p), 1.0))


def reduce_pseudogradients(worker_comm: PyTree, cfg: CompressionConfig,
                           participation: jax.Array | None = None) -> PyTree:
    """Reduce per-worker wire buffers into the pseudogradient Psi.

    ``worker_comm`` leaves are the worker stage's output: dense [K, ...]
    deltas for ``kind='none'`` (bit-exact legacy path), wire packets
    otherwise (Q1 / top-k applied, with or without EF, by the caller). For
    the 'a2a_rs_ag' quantized collective the reduced shard is re-encoded
    through a second wire buffer (Q2) and decoded (D2) before the
    all-gather, exactly the paper's two quantization points.

    With an elastic ``participation`` mask ([K] float32 {0,1}) the mean runs
    over the surviving subset only (:func:`participation_mean`) — a dropped
    worker's rows are decoded but carry weight 0, matching a collective that
    never received its packet. Wire row layouts fold K into the leading row
    axis with per-worker metadata, so a dropped worker's (stale) buffer
    never contaminates the survivors' encodings.
    """
    if cfg.kind == "none":
        return jax.tree.map(
            lambda d: participation_mean(d.astype(jnp.float32), participation),
            worker_comm)

    def per_leaf(w):
        vals = decode_leaf(w, impl=cfg.wire_impl)  # D1: [K, ...] f32
        psi = participation_mean(vals, participation)
        if cfg.kind == "quant" and cfg.collective == "a2a_rs_ag":
            w2 = encode_leaf(psi, cfg, batch_ndim=0)  # Q2: re-quantize shard
            psi = decode_leaf(w2, impl=cfg.wire_impl)  # D2: after all-gather
        return psi

    return jax.tree.map(per_leaf, worker_comm, is_leaf=is_wire)


def _leaf_wire_pipeline(d: jax.Array, e: jax.Array | None,
                        cfg: CompressionConfig,
                        participation: jax.Array | None = None):
    """The full per-leaf wire path on a [K, ...] delta leaf: (EF accumulate
    ->) Q1 encode -> D1 decode -> mean over K (-> Q2/D2 for a2a_rs_ag).
    Mirrors ``compress``/``error_feedback`` + :func:`reduce_pseudogradients`
    leafwise; ``participation`` restricts the mean to surviving workers.
    Returns ``(psi f32, new_residual f32 | None)``."""
    if e is not None:
        acc = cfg.ef_decay * e.astype(jnp.float32) + d.astype(jnp.float32)
        w = encode_leaf(acc, cfg, batch_ndim=1)
    else:
        acc = None
        w = encode_leaf(d, cfg, batch_ndim=1)
    vals = decode_leaf(w, impl=cfg.wire_impl)  # D1: the true reconstruction
    new_e = acc - vals if acc is not None else None
    psi = participation_mean(vals, participation)
    if cfg.kind == "quant" and cfg.collective == "a2a_rs_ag":
        w2 = encode_leaf(psi, cfg, batch_ndim=0)
        psi = decode_leaf(w2, impl=cfg.wire_impl)
    return psi, new_e


def segment_sync_update(deltas: PyTree, residuals: PyTree | None,
                        mask: PyTree, cfg: CompressionConfig,
                        participation: jax.Array | None = None):
    """One streaming segment's worker+reduce stages with **wire-row
    subsetting** (ROADMAP item): the concrete partition mask decides, per
    leaf, whether to encode the whole leaf, nothing, only its owned L-rows
    (gathered into a genuinely smaller wire buffer — what a real streaming
    collective would ship), or to fall back to the legacy full-size masked
    encode where subsetting would split wire rows
    (:func:`repro.core.streaming.subset_plan`).

    ``deltas`` leaves are the mask-multiplied [K, ...] worker deltas;
    ``residuals`` is the K-stacked EF tree or ``None``. Returns
    ``(psi, new_residuals)``. For ``'skip'``/``'rows'`` leaves psi is
    exactly zero outside the partition and unowned residual rows come back
    unchanged; a ``'legacy'`` leaf runs the full-size masked encode, so its
    unowned psi entries are only quantization-level small and its unowned
    residual rows are EF-decayed — callers MUST still mask psi and
    mask-merge the residuals (``outer_step``/``OuterOptimizer.step`` do).
    """
    from repro.core.streaming import subset_plan

    def per_leaf(d, e, m):
        plan, idx = subset_plan(m, d.shape[1:], cfg)
        if plan == "skip":
            return jnp.zeros(d.shape[1:], jnp.float32), e
        if plan == "rows":
            e_in = e[:, idx] if e is not None else None
            psi_sub, new_e_sub = _leaf_wire_pipeline(
                d[:, idx], e_in, cfg, participation=participation)
            psi = jnp.zeros(d.shape[1:], jnp.float32).at[idx].set(psi_sub)
            new_e = (e.astype(jnp.float32).at[:, idx].set(new_e_sub)
                     if e is not None else None)
            return psi, new_e
        # 'all' / 'legacy'
        return _leaf_wire_pipeline(d, e, cfg, participation=participation)

    if residuals is None:
        out = jax.tree.map(lambda d, m: per_leaf(d, None, m), deltas, mask)
    else:
        out = jax.tree.map(per_leaf, deltas, residuals, mask)
    is_pair = lambda t: isinstance(t, tuple)  # noqa: E731
    psi = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
    if residuals is None:
        return psi, None
    return psi, jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)


def reduce_mean(cfg: CompressionConfig,
                participation: jax.Array | None = None):
    """The pseudogradient all-reduce as a stateless transform stage:
    [K, ...]-stacked wire buffers (or dense deltas for kind='none') -> Psi
    (mean over K, + Q2/D2 for the a2a_rs_ag quantized collective).

    ``participation`` (a traced [K] {0,1} mask, closed over at trace time by
    :class:`repro.core.diloco.OuterOptimizer`) restricts the mean to the
    round's surviving workers; ``None`` emits the exact dense program.
    """
    from repro.optim.transform import stateless

    return stateless(lambda comm, _params: reduce_pseudogradients(
        comm, cfg, participation=participation))


# ---------------------------------------------------------------------------
# Byte accounting
# ---------------------------------------------------------------------------


def _leaf_sync_bytes(leaf, cfg: CompressionConfig, n_workers: int) -> float:
    """Measured per-sync wire bytes *per worker* for one parameter leaf.

    Buffer sizes come from ``jax.eval_shape`` over the real encode path, so
    codes, row metadata, indices, and bit-packing padding are all counted
    exactly as allocated. Phases per collective:

    * dense (kind='none'):  fp32 reduce-scatter + all-gather = 2 full trees;
    * quant 'a2a_rs_ag':    the worker's Q1 buffer out + the Q2 buffer in;
    * quant/top-k 'gather': every worker receives all K workers' buffers
      (all-gather bandwidth grows with K — paper §2).
    """
    K = n_workers
    shape, dtype = tuple(leaf.shape), jnp.dtype(leaf.dtype)
    if cfg.kind == "none":
        return 2.0 * float(np.prod(shape)) * 4  # fp32 on the wire
    stacked = jax.ShapeDtypeStruct((K, *shape), jnp.float32)
    w1 = jax.eval_shape(
        lambda x: encode_leaf(x, cfg, batch_ndim=1, impl="jnp"), stacked)
    q1_per_worker = wire_tree_bytes(w1) / K
    if cfg.kind == "quant" and cfg.collective == "a2a_rs_ag":
        w2 = jax.eval_shape(
            lambda x: encode_leaf(x, cfg, batch_ndim=0, impl="jnp"),
            jax.ShapeDtypeStruct(shape, jnp.float32))
        return q1_per_worker + wire_tree_bytes(w2)
    return q1_per_worker * K  # gather: receive every worker's buffer


def measured_sync_bytes(params: PyTree, cfg: CompressionConfig,
                        n_workers: int, mask: PyTree | None = None,
                        outer_enabled: bool = True) -> int:
    """Measured wire bytes per outer sync **per worker**, from the actual
    buffers the collective moves.

    ``params`` may be concrete or abstract (only shapes/dtypes are read).
    With a streaming partition ``mask`` (concrete {0,1} arrays) the
    accounting follows the same per-leaf :func:`subset_plan` the segment
    sync executes: wholly-owned leaves are counted in full, unowned leaves
    not at all, and ``'rows'`` leaves are ``jax.eval_shape``-measured on the
    *subset* shape the sync actually encodes — so per-segment totals sum
    exactly to the dense single-sync total. Only the ``'legacy'`` fallback
    (partial ownership that would split wire rows) still scales full-size
    buffer bytes by the masked-row fraction. With ``outer_enabled=False``
    (the DP-degenerate config) the sync is the K-way parameter average: a
    dense fp32 all-reduce for K > 1, nothing at all for K == 1.
    """
    from repro.core.streaming import subset_plan

    leaves = jax.tree.leaves(params)
    mask_leaves = (jax.tree.leaves(mask) if mask is not None
                   else [None] * len(leaves))
    total = 0.0
    for p, m in zip(leaves, mask_leaves):
        if not outer_enabled:
            frac = 1.0 if m is None else float(np.asarray(m, np.float32).mean())
            total += frac * (0.0 if n_workers == 1
                             else 2.0 * float(np.prod(tuple(p.shape))) * 4)
            continue
        if m is None or cfg.kind == "none":
            frac = 1.0 if m is None else float(np.asarray(m, np.float32).mean())
            total += frac * _leaf_sync_bytes(p, cfg, n_workers)
            continue
        plan, idx = subset_plan(m, tuple(p.shape), cfg)
        if plan == "skip":
            continue
        if plan == "rows":  # bytes of the buffers the subset encode emits
            sub = jax.ShapeDtypeStruct((len(idx), *p.shape[1:]), p.dtype)
            total += _leaf_sync_bytes(sub, cfg, n_workers)
        elif plan == "all":
            total += _leaf_sync_bytes(p, cfg, n_workers)
        else:  # 'legacy': full-size masked encode, fraction-accounted
            frac = float(np.asarray(m, np.float32).mean())
            total += frac * _leaf_sync_bytes(p, cfg, n_workers)
    return int(round(total))


def measured_compression_ratio(params: PyTree, cfg: CompressionConfig,
                               n_workers: int) -> float:
    """Measured wire bytes vs the dense fp32 collective on the same tree.

    Replaces ``CompressionConfig.compression_ratio()`` (the closed-form
    model) wherever a representative parameter tree exists: the measured
    ratio includes row metadata, index widths, packing padding, and the
    K-scaling of the gather collective.
    """
    dense = measured_sync_bytes(params, CompressionConfig(kind="none"), n_workers)
    return measured_sync_bytes(params, cfg, n_workers) / max(dense, 1)


def collective_bytes_tree(params: PyTree, cfg: CompressionConfig, n_workers: int) -> dict:
    """*Modeled* wire bytes per outer sync (per worker) — Tab. 10 / Fig. 16.

    dense ring all-reduce:   2 * P * 4 bytes (reduce-scatter + all-gather)
    quant a2a_rs + ring ag:  2 * P * bits/8
    top-k all-gather:        K * kept * (4 + 4) bytes (value + index), since
                             all-gather bandwidth grows with K (paper §2).

    Kept as the closed-form estimate for parameter counts without a concrete
    tree; prefer :func:`measured_sync_bytes` when buffers exist.
    """
    n = 0
    for leaf in jax.tree.leaves(params):
        size = 1
        for d in leaf.shape:
            size *= int(d)
        n += size
    if cfg.kind == "none":
        per_worker = 2 * n * 4
    elif cfg.kind == "quant":
        per_worker = int(2 * n * cfg.bits / 8)
    elif cfg.kind == "topk":
        kept = int(n * cfg.topk_frac)
        per_worker = n_workers * kept * 8
    else:
        raise ValueError(cfg.kind)
    return {"params": n, "bytes_per_sync_per_worker": per_worker}
