"""Communication collectives for the pseudogradient all-reduce.

The paper (§2 "Collectives for compressed communication", App. C.1) models an
**all-to-all reduce-scatter followed by a ring all-gather**: each worker's
quantized pseudogradient shard is dequantized and reduced *once* in high
precision on its owner device, re-quantized, and all-gathered — exactly two
quantize/dequantize ops total, avoiding the per-hop error accumulation of a
ring all-reduce. Top-k instead uses an all-gather + local reduce (one
compression).

Workers live on a stacked leading K axis (sharded over the `pod` mesh axis in
production), so ``mean over axis 0`` lowers to the cross-pod all-reduce; the
quantization placement here reproduces the *values* the modeled collective
would produce, which is what training dynamics (and our experiments) see.

``collective_bytes_tree`` accounts wire bytes per method for the wallclock
model (Tab. 10 / Fig. 16).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.compression import CompressionConfig, compress_tensor

PyTree = Any


def reduce_pseudogradients(worker_deltas: PyTree, cfg: CompressionConfig) -> PyTree:
    """Average compressed per-worker deltas [K, ...] into a pseudogradient.

    ``worker_deltas`` leaves are the *already worker-side compressed* deltas
    (Q1 / top-k applied, with or without EF, by the caller). For the
    'a2a_rs_ag' quantized collective we apply the second quantization (Q2)
    to the reduced value before the all-gather.
    """

    def per_leaf(d):
        psi = jnp.mean(d.astype(jnp.float32), axis=0)
        if cfg.kind == "quant" and cfg.collective == "a2a_rs_ag":
            psi = compress_tensor(psi, cfg)  # Q2: re-quantize reduced shard
        return psi

    return jax.tree.map(per_leaf, worker_deltas)


def reduce_mean(cfg: CompressionConfig):
    """The pseudogradient all-reduce as a stateless transform stage:
    [K, ...]-stacked (compressed) deltas -> Psi (mean over K, + Q2 for the
    a2a_rs_ag quantized collective)."""
    from repro.optim.transform import stateless

    return stateless(lambda comm, _params: reduce_pseudogradients(comm, cfg))


def collective_bytes_tree(params: PyTree, cfg: CompressionConfig, n_workers: int) -> dict:
    """Wire bytes per outer sync under the modeled collectives (per worker).

    dense ring all-reduce:   2 * P * 4 bytes (reduce-scatter + all-gather)
    quant a2a_rs + ring ag:  2 * P * bits/8
    top-k all-gather:        K * kept * (4 + 4) bytes (value + index), since
                             all-gather bandwidth grows with K (paper §2).
    """
    n = 0
    for leaf in jax.tree.leaves(params):
        size = 1
        for d in leaf.shape:
            size *= int(d)
        n += size
    if cfg.kind == "none":
        per_worker = 2 * n * 4
    elif cfg.kind == "quant":
        per_worker = int(2 * n * cfg.bits / 8)
    elif cfg.kind == "topk":
        kept = int(n * cfg.topk_frac)
        per_worker = n_workers * kept * 8
    else:
        raise ValueError(cfg.kind)
    return {"params": n, "bytes_per_sync_per_worker": per_worker}
