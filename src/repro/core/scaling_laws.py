"""Scaling-law fitting machinery (paper §7, Tab. 2/6, Figs. 10/13/17/18).

Power laws L(C) = a*C^alpha (+ L_irr), fit by minimizing a Huber loss on
log-space residuals with L-BFGS-B from many random restarts; a joint
irreducible loss can be shared across methods via the paper's three-phase
grid search. Also: critical-batch-size laws B_crit(D) = a*D^alpha, and the
iso-loss training-time decomposition of Eq. (6).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np
from scipy.optimize import minimize


def huber(x: np.ndarray, delta: float = 1e-3) -> np.ndarray:
    a = np.abs(x)
    return np.where(a <= delta, 0.5 * x * x, delta * (a - 0.5 * delta))


@dataclasses.dataclass
class PowerLawFit:
    a: float
    alpha: float
    irr: float
    objective: float

    def predict(self, C: np.ndarray) -> np.ndarray:
        return self.a * np.asarray(C, float) ** self.alpha + self.irr

    def residuals(self, C, L) -> np.ndarray:
        return np.abs(np.log(np.asarray(L, float)) - np.log(self.predict(C)))


def _fit_once(C, L, irr, x0, fit_irr: bool) -> tuple[np.ndarray, float]:
    logC, logL = np.log(C), np.log(L)

    def obj(x):
        la, alpha = x[0], x[1]
        c = np.exp(x[2]) if fit_irr else irr
        pred = np.exp(la + alpha * logC) + c
        return float(np.sum(huber(np.log(pred) - logL)))

    res = minimize(obj, x0, method="L-BFGS-B", options={"maxiter": 15_000})
    return res.x, float(res.fun)


def fit_power_law(C: Sequence[float], L: Sequence[float], irr: float = 0.0,
                  fit_irr: bool = False, restarts: int = 64, seed: int = 0) -> PowerLawFit:
    """Fit L(C) = a C^alpha + irr. ``fit_irr`` learns a per-fit irreducible."""
    C = np.asarray(C, float)
    L = np.asarray(L, float)
    rng = np.random.default_rng(seed)
    best_x, best_f = None, np.inf
    for _ in range(restarts):
        x0 = np.array([
            rng.normal(np.log(L.max()), 2.0),
            -abs(rng.normal(0.2, 0.15)),
            np.log(max(L.min() * rng.uniform(0.2, 0.9), 1e-6)),
        ])
        x0 = x0 if fit_irr else x0[:2]
        try:
            x, f = _fit_once(C, L, irr, x0 if fit_irr else np.concatenate([x0, [0.0]])[:2], fit_irr)
        except Exception:
            continue
        if f < best_f:
            best_x, best_f = x, f
    la, alpha = best_x[0], best_x[1]
    c = float(np.exp(best_x[2])) if fit_irr else irr
    return PowerLawFit(a=float(np.exp(la)), alpha=float(alpha), irr=c, objective=best_f)


def fit_joint_irreducible(datasets: dict[str, tuple[Sequence[float], Sequence[float]]],
                          n_grid: int = 40, restarts: int = 16, seed: int = 0
                          ) -> tuple[float, dict[str, PowerLawFit]]:
    """Paper's three-phase shared-L_irr fit: coarse grid over L_irr, zoom,
    then a final refit of every method at the selected L_irr."""
    all_L = np.concatenate([np.asarray(L, float) for _, L in datasets.values()])
    lo, hi = 1e-3, all_L.min() * 0.999

    def total_obj(irr):
        tot = 0.0
        for C, L in datasets.values():
            f = fit_power_law(C, L, irr=irr, restarts=restarts, seed=seed)
            tot += f.objective
        return tot

    # phase 1: coarse
    grid = np.linspace(lo, hi, n_grid)
    objs = [total_obj(g) for g in grid]
    best = int(np.argmin(objs))
    # phase 2: zoom around the best candidate
    lo2 = grid[max(best - 1, 0)]
    hi2 = grid[min(best + 1, n_grid - 1)]
    grid2 = np.linspace(lo2, hi2, 10)
    objs2 = [total_obj(g) for g in grid2]
    irr = float(grid2[int(np.argmin(objs2))])
    # phase 3: full refit
    fits = {k: fit_power_law(C, L, irr=irr, restarts=restarts * 4, seed=seed)
            for k, (C, L) in datasets.items()}
    return irr, fits


# ---------------------------------------------------------------------------
# Critical batch size (Fig. 12/13) and iso-loss efficiency (Eq. 6)
# ---------------------------------------------------------------------------


def optimal_and_critical_batch(batches: Sequence[float], losses: Sequence[float],
                               tol: float = 0.01) -> tuple[float, float]:
    """B_opt = argmin L; B_crit = largest B with L(B) <= (1+tol) L(B_opt),
    log-linearly interpolated between swept batch sizes."""
    b = np.asarray(batches, float)
    ls = np.asarray(losses, float)
    order = np.argsort(b)
    b, ls = b[order], ls[order]
    i_opt = int(np.argmin(ls))
    b_opt, l_opt = b[i_opt], ls[i_opt]
    thresh = (1.0 + tol) * l_opt
    b_crit = b_opt
    for i in range(i_opt, len(b)):
        if ls[i] <= thresh:
            b_crit = b[i]
        else:  # interpolate crossing in log-B
            l0, l1 = ls[i - 1], ls[i]
            if l1 > l0:
                t = (thresh - l0) / (l1 - l0)
                b_crit = float(np.exp(np.log(b[i - 1]) + t * (np.log(b[i]) - np.log(b[i - 1]))))
            break
    return float(b_opt), float(b_crit)


def iso_loss_time_ratio(loss_fit_ref: PowerLawFit, cbs_fit_ref: PowerLawFit,
                        loss_fit: PowerLawFit, cbs_fit: PowerLawFit,
                        target_loss: float, tokens_per_flop: float = 1.0 / 6.0
                        ) -> dict[str, float]:
    """Eq. (6): T_ref(L)/T_m(L) = compute-savings x parallelism-advantage,
    with T = C / B_crit(C) and D derived from C via chinchilla C = 6 N D,
    D = 20 N  =>  D = sqrt(C * 20 / 6)."""

    def invert_loss(fit: PowerLawFit, L: float) -> float:
        return ((L - fit.irr) / fit.a) ** (1.0 / fit.alpha)

    def seq_time(loss_fit, cbs_fit, L):
        C = invert_loss(loss_fit, L)
        D = np.sqrt(C * 20.0 / 6.0)
        B = cbs_fit.a * D ** cbs_fit.alpha
        return C / B, C, B

    t_ref, c_ref, b_ref = seq_time(loss_fit_ref, cbs_fit_ref, target_loss)
    t_m, c_m, b_m = seq_time(loss_fit, cbs_fit, target_loss)
    return {
        "time_ratio": t_ref / t_m,
        "compute_savings": c_ref / c_m,
        "parallelism_advantage": b_m / b_ref,
    }
