"""Idealized wall-clock / bandwidth model (paper Fig. 9/16/20, Tab. 10).

Training time = compute + optimizer overhead + communication, where DP
communicates 2*P*bytes every step (ring all-reduce) and DiLoCo/MuLoCo
communicate the (optionally compressed) pseudogradient every H steps.
Mirrors the paper's estimates built from measured step times; here the
compute term comes from the roofline model instead of H100 measurements.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    peak_flops: float = 197e12  # bf16 / chip (TPU v5e)
    hbm_bw: float = 819e9
    link_bw: float = 50e9  # ICI per link
    chips: int = 256
    assumed_mfu: float = 0.4


@dataclasses.dataclass(frozen=True)
class RunSpec:
    n_params: float
    n_active_params: float  # = n_params for dense
    batch_tokens: float
    seq_len: int
    n_steps: int
    sync_interval: int = 1  # H (1 => DP: communicate every step)
    n_workers: int = 1
    # wire bytes vs fp32. Prefer the *measured* ratio from real wire buffers
    # (repro.core.collectives.measured_compression_ratio, which counts codes
    # + row metadata + indices + packing padding) over the closed-form
    # CompressionConfig.compression_ratio() model when a representative
    # parameter tree exists.
    compression_ratio: float = 1.0
    # measured wire bytes per sync per worker; when > 0 it overrides the
    # ratio model above (set it from collectives.measured_sync_bytes)
    wire_bytes_per_sync: float = 0.0
    optimizer_overhead: float = 0.0096  # paper Tab. 9: +0.96% for Muon


def step_compute_time(spec: RunSpec, hw: HardwareModel) -> float:
    flops = 6.0 * spec.n_active_params * spec.batch_tokens
    return flops / (hw.chips * hw.peak_flops * hw.assumed_mfu)


def sync_comm_time(spec: RunSpec, bandwidth_bps: float) -> float:
    """Cross-pool pseudogradient bytes per sync / available bandwidth.

    Uses the measured per-sync wire bytes when the spec carries them;
    otherwise the modeled ring all-reduce volume 2*P*4 bytes scaled by the
    compression ratio. ``bandwidth_bps`` is bits/s (paper quotes Gbit/s
    links)."""
    bytes_wire = (spec.wire_bytes_per_sync
                  or 2.0 * spec.n_params * 4.0 * spec.compression_ratio)
    return bytes_wire * 8.0 / bandwidth_bps


def training_time_hours(spec: RunSpec, bandwidth_bps: float, hw: HardwareModel = HardwareModel()) -> float:
    t_step = step_compute_time(spec, hw) * (1.0 + spec.optimizer_overhead)
    t_sync = sync_comm_time(spec, bandwidth_bps)
    n_syncs = spec.n_steps / spec.sync_interval
    total = spec.n_steps * t_step + n_syncs * t_sync
    return total / 3600.0


def compute_utilization(spec: RunSpec, bandwidth_bps: float, hw: HardwareModel = HardwareModel()) -> float:
    """Fraction of time doing compute (paper Fig. 16), assuming no overlap."""
    t_step = step_compute_time(spec, hw)
    t_sync_per_step = sync_comm_time(spec, bandwidth_bps) / spec.sync_interval
    return t_step / (t_step + t_sync_per_step)
