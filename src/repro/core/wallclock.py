"""Idealized wall-clock / bandwidth model (paper Fig. 9/16/20, Tab. 10).

Training time = compute + optimizer overhead + communication, where DP
communicates 2*P*bytes every step (ring all-reduce) and DiLoCo/MuLoCo
communicate the (optionally compressed) pseudogradient every H steps.
Mirrors the paper's estimates built from measured step times; here the
compute term comes from the roofline model instead of H100 measurements.

:class:`StragglerModel` extends the deterministic estimate with per-worker
latency variation: each round every worker draws a lognormal latency
multiplier and (independently) a drop coin, the sync waits for the slowest
*surviving* worker, and the per-round wall-clock distribution answers
"what does p99 worker latency cost at K=16?" — the question elastic DiLoCo
(worker churn + delayed sync) exists to improve on.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    peak_flops: float = 197e12  # bf16 / chip (TPU v5e)
    hbm_bw: float = 819e9
    link_bw: float = 50e9  # ICI per link
    chips: int = 256
    assumed_mfu: float = 0.4


@dataclasses.dataclass(frozen=True)
class RunSpec:
    n_params: float
    n_active_params: float  # = n_params for dense
    batch_tokens: float
    seq_len: int
    n_steps: int
    sync_interval: int = 1  # H (1 => DP: communicate every step)
    n_workers: int = 1
    # wire bytes vs fp32. Prefer the *measured* ratio from real wire buffers
    # (repro.core.collectives.measured_compression_ratio, which counts codes
    # + row metadata + indices + packing padding) over the closed-form
    # CompressionConfig.compression_ratio() model when a representative
    # parameter tree exists.
    compression_ratio: float = 1.0
    # measured wire bytes per sync per worker; when > 0 it overrides the
    # ratio model above (set it from collectives.measured_sync_bytes)
    wire_bytes_per_sync: float = 0.0
    optimizer_overhead: float = 0.0096  # paper Tab. 9: +0.96% for Muon


def step_compute_time(spec: RunSpec, hw: HardwareModel) -> float:
    flops = 6.0 * spec.n_active_params * spec.batch_tokens
    return flops / (hw.chips * hw.peak_flops * hw.assumed_mfu)


def sync_comm_time(spec: RunSpec, bandwidth_bps: float) -> float:
    """Cross-pool pseudogradient bytes per sync / available bandwidth.

    Uses the measured per-sync wire bytes when the spec carries them;
    otherwise the modeled ring all-reduce volume 2*P*4 bytes scaled by the
    compression ratio. ``bandwidth_bps`` is bits/s (paper quotes Gbit/s
    links)."""
    bytes_wire = (spec.wire_bytes_per_sync
                  or 2.0 * spec.n_params * 4.0 * spec.compression_ratio)
    return bytes_wire * 8.0 / bandwidth_bps


def training_time_hours(spec: RunSpec, bandwidth_bps: float, hw: HardwareModel = HardwareModel()) -> float:
    t_step = step_compute_time(spec, hw) * (1.0 + spec.optimizer_overhead)
    t_sync = sync_comm_time(spec, bandwidth_bps)
    n_syncs = spec.n_steps / spec.sync_interval
    total = spec.n_steps * t_step + n_syncs * t_sync
    return total / 3600.0


def compute_utilization(spec: RunSpec, bandwidth_bps: float, hw: HardwareModel = HardwareModel()) -> float:
    """Fraction of time doing compute (paper Fig. 16), assuming no overlap."""
    t_step = step_compute_time(spec, hw)
    t_sync_per_step = sync_comm_time(spec, bandwidth_bps) / spec.sync_interval
    return t_step / (t_step + t_sync_per_step)


# ---------------------------------------------------------------------------
# Straggler / churn extension: per-round wall-clock as a distribution
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StragglerModel:
    """Per-worker latency + drop model layered over the deterministic estimate.

    Every round, worker k draws a lognormal latency multiplier
    ``L_k = exp(sigma*z - sigma^2/2)`` (mean exactly 1, so sigma only widens
    the distribution without inflating the average) and an independent drop
    coin. The lockstep sync waits for the *slowest surviving* worker:
    ``t_round = H * t_step * (1 + overhead) * max_k(L_k) + t_sync`` over the
    active set. Dropped workers leave the max — elastic DiLoCo's whole wager
    is that excluding them buys back the tail.

    The drop coins use common random numbers (one uniform per worker-round,
    dropped iff ``u < drop_prob``), so raising ``drop_prob`` only ever
    *removes* workers from the max — p50/p99 round times are monotonically
    non-increasing in the drop rate, sampling noise included. At least one
    worker always survives: the largest draw — the last worker any drop
    rate would evict — is kept, so the fallback survivor is a member of
    every lower-drop active set and monotonicity holds through the
    all-drop regime too (matching :class:`repro.core.faults.FaultPlan`). With ``sigma == 0`` every
    multiplier is exactly 1.0 and with ``drop_prob == 0`` the active set is
    everyone, so the sampled distribution collapses, bit-for-bit, to the
    deterministic per-round estimate of :func:`training_time_hours`.
    """

    sigma: float = 0.0  # lognormal sigma of the per-worker latency multiplier
    drop_prob: float = 0.0  # per-(round, worker) drop probability
    seed: int = 0
    n_rounds: int = 2048  # Monte-Carlo rounds sampled

    @property
    def is_trivial(self) -> bool:
        return self.sigma == 0.0 and self.drop_prob == 0.0

    def sample(self, n_workers: int) -> tuple[np.ndarray, np.ndarray]:
        """(latency multipliers [n_rounds, K], active mask [n_rounds, K])."""
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, n_workers]))
        u = rng.random((self.n_rounds, n_workers))
        z = rng.standard_normal((self.n_rounds, n_workers))
        lat = np.exp(self.sigma * z - 0.5 * self.sigma * self.sigma)
        active = u >= self.drop_prob
        all_drop = ~active.any(axis=1)
        if all_drop.any():
            rows = np.nonzero(all_drop)[0]
            active[rows, np.argmax(u[rows], axis=1)] = True
        return lat, active


def straggler_round_times(spec: RunSpec, bandwidth_bps: float,
                          model: StragglerModel,
                          hw: HardwareModel = HardwareModel()) -> np.ndarray:
    """Sampled per-round wall-clock seconds ([model.n_rounds])."""
    t_step = step_compute_time(spec, hw) * (1.0 + spec.optimizer_overhead)
    t_sync = sync_comm_time(spec, bandwidth_bps)
    lat, active = model.sample(spec.n_workers)
    slowest = np.where(active, lat, 0.0).max(axis=1)
    return spec.sync_interval * t_step * slowest + t_sync


def straggler_stats(spec: RunSpec, bandwidth_bps: float,
                    model: StragglerModel,
                    hw: HardwareModel = HardwareModel()) -> dict:
    """p50/p99/mean round wall-clock under the straggler model.

    ``deterministic`` is the no-variance lockstep round time; ``p99_over_det``
    is the tail tax a lockstep sync pays at this sigma/drop rate.
    """
    times = straggler_round_times(spec, bandwidth_bps, model, hw)
    t_step = step_compute_time(spec, hw) * (1.0 + spec.optimizer_overhead)
    det = spec.sync_interval * t_step + sync_comm_time(spec, bandwidth_bps)
    return {
        "p50_round_s": float(np.percentile(times, 50)),
        "p99_round_s": float(np.percentile(times, 99)),
        "mean_round_s": float(times.mean()),
        "deterministic_round_s": float(det),
        "p99_over_det": float(np.percentile(times, 99) / det),
    }
