"""The paper's primary contribution: DiLoCo/MuLoCo distributed optimization,
compressed + streaming communication, and the pseudogradient analysis suite."""
from repro.core.compression import CompressionConfig, compress_tensor, compress_tree  # noqa: F401
from repro.core.diloco import (  # noqa: F401
    DiLoCoConfig,
    compute_deltas,
    diloco_init,
    diloco_round,
    dp_config,
    dp_init,
    dp_step,
    inner_step,
    make_optimizer,
    make_outer,
    make_streaming_masks,
    outer_step,
    OuterOptimizer,
)
from repro.core.health import HealthConfig, health_init, health_update  # noqa: F401
