"""Pseudogradient analysis tools (paper §4.2-4.3, Figs. 2-5).

Implements: cosine alignment of pseudogradients / optimizer steps, singular
value spectra before/after averaging, the top-S interference gap (Def. 4.1),
nuclear norms via the orthonormal factor, and the exact Proposition 4.2
identity (used as a property test and in benchmarks).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_leaves_with_paths

PyTree = Any


def cosine(a: jax.Array, b: jax.Array, eps: float = 1e-12) -> jax.Array:
    a = a.reshape(-1).astype(jnp.float32)
    b = b.reshape(-1).astype(jnp.float32)
    return jnp.vdot(a, b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b) + eps)


def hidden_matrix_leaves(tree: PyTree) -> list[tuple[str, jax.Array]]:
    """Leaves that Muon treats as hidden matrices (per-layer matrices)."""
    from repro.optim.muon import muon_label

    out = []
    for path, leaf in tree_leaves_with_paths(tree):
        if muon_label(path, leaf) == "muon":
            out.append((path, leaf))
    return out


def per_matrix_cosines(tree_a: PyTree, tree_b: PyTree) -> dict[str, float]:
    """Cosine similarity per hidden weight matrix (paper Fig. 2 box plots).

    Stacked [L, m, n] leaves contribute one cosine per layer slice."""
    cos = {}
    a_leaves = dict(hidden_matrix_leaves(tree_a))
    b_leaves = dict(hidden_matrix_leaves(tree_b))
    for path, a in a_leaves.items():
        b = b_leaves[path]
        if a.ndim > 2:
            a2 = a.reshape((-1, *a.shape[-2:]))
            b2 = b.reshape((-1, *b.shape[-2:]))
            cs = jax.vmap(cosine)(a2, b2)
            for i in range(cs.shape[0]):
                cos[f"{path}[{i}]"] = float(cs[i])
        else:
            cos[path] = float(cosine(a, b))
    return cos


def singular_values(x: jax.Array) -> jax.Array:
    return jnp.linalg.svd(x.astype(jnp.float32), compute_uv=False)


def orthonormal_factor(x: jax.Array) -> jax.Array:
    """Psi* = U V^T from the SVD of x."""
    u, _, vt = jnp.linalg.svd(x.astype(jnp.float32), full_matrices=False)
    return u @ vt


def nuclear_norm(x: jax.Array) -> jax.Array:
    return jnp.sum(singular_values(x))


def interference_gap(worker_mats: jax.Array, s_frac: float = 0.05) -> jax.Array:
    """Top-S interference gap G_S (Def. 4.1).

    worker_mats: [K, m, n]. G_S = mean_k topS(σ(Δ_k)) − topS(σ(mean Δ)).
    """
    K, m, n = worker_mats.shape
    r = min(m, n)
    S = max(int(round(s_frac * r)), 1)
    sv_workers = jax.vmap(singular_values)(worker_mats)  # [K, r]
    mean_mat = jnp.mean(worker_mats, axis=0)
    sv_mean = singular_values(mean_mat)
    return jnp.mean(jnp.sum(sv_workers[:, :S], axis=1)) - jnp.sum(sv_mean[:S])


def prop42_nuclear_identity(steps: jax.Array, alphas: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Proposition 4.2: for Ψ = (1/K) Σ_k Σ_h α_h ψ^(h,k),

        ‖Ψ‖_* = (√r / K) Σ_{k,h} ρ^(h,k) α_h ‖ψ^(h,k)‖_F

    steps: [K, H, m, n]; alphas: [H]. Returns (lhs, rhs) — equal up to fp error.
    """
    K, H, m, n = steps.shape
    r = min(m, n)
    psi = jnp.einsum("h,khmn->mn", alphas, steps) / K
    lhs = nuclear_norm(psi)
    psi_star = orthonormal_factor(psi)
    norm_star = jnp.sqrt(jnp.asarray(r, jnp.float32))

    fro = jnp.sqrt(jnp.sum(steps.astype(jnp.float32) ** 2, axis=(-2, -1)))  # [K, H]
    inner = jnp.einsum("khmn,mn->kh", steps.astype(jnp.float32), psi_star)
    rho = inner / (fro * norm_star + 1e-30)
    rhs = norm_star / K * jnp.sum(rho * alphas[None, :] * fro)
    return lhs, rhs


def frobenius_norms(tree: PyTree) -> dict[str, float]:
    """Per-hidden-matrix Frobenius norms (paper Fig. 5 step-norm traces)."""
    out = {}
    for path, leaf in hidden_matrix_leaves(tree):
        x = leaf.astype(jnp.float32)
        if x.ndim > 2:
            x = x.reshape((-1, *x.shape[-2:]))
            norms = jnp.sqrt(jnp.sum(x * x, axis=(-2, -1)))
            for i in range(norms.shape[0]):
                out[f"{path}[{i}]"] = float(norms[i])
        else:
            out[path] = float(jnp.linalg.norm(x))
    return out
