"""Wire formats for the compressed pseudogradient collectives.

The compression stages used to be *value-semantics* — they returned the
dequantized tensor the receiver would reconstruct and only pretended codes
were sent. This module materializes what actually crosses the wire:

* **linear quantization** -> :class:`QuantWire`: bit-packed uint8 codes
  (8/bits codes per byte, :func:`repro.kernels.quantize.pack_codes`) plus
  per-row fp32 ``lo``/``scale`` metadata, produced by the fused Pallas
  ``rowwise_quantize`` kernel (``wire_impl='pallas'``; on a mesh its rows
  shard_map over ('pod','data') via the kernel-partitioning routing) or an
  elementwise-identical jnp path (``'jnp'``);
* **statistical quantization** -> :class:`CodebookWire`: bit-packed codes
  plus the per-row quantile codebook (2^bits fp32 levels);
* **top-k** -> :class:`TopKWire`: explicit (int32 index, fp32 value) pairs
  per worker (:mod:`repro.kernels.topk_pack`).

Row layout mirrors the value-semantics compressors exactly: ``rowwise=True``
quantizes per last-axis row, otherwise the whole (per-worker) leaf is one
row. Worker-stacked ``[K, ...]`` leaves fold K into the row axis so one
kernel call encodes all workers — no vmap over the Pallas call.

Receivers reconstruct **from the wire buffers only**
(:func:`decode_leaf`), so the error-feedback residual and the reduce see the
same reconstruction the network would deliver. Byte accounting
(:func:`wire_tree_bytes`) reads sizes off the actual buffers (works on
arrays and ``ShapeDtypeStruct``), which is what the measured ``comm_bytes``
metric is built from (:func:`repro.core.collectives.measured_sync_bytes`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# ---------------------------------------------------------------------------
# Wire packet pytrees (buffers are children; layout metadata is static)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QuantWire:
    """Linear-quantization wire buffer: packed codes + per-row (lo, scale)."""

    packed: Any  # uint8 [rows, packed_width(cols, bits)]
    lo: Any  # f32 [rows, 1]
    scale: Any  # f32 [rows, 1]
    shape: tuple  # original leaf shape (static)
    cols: int  # codes per row before packing (static)
    bits: int  # code width (static)


@dataclasses.dataclass(frozen=True)
class CodebookWire:
    """Statistical-quantization wire buffer: packed codes + quantile levels."""

    packed: Any  # uint8 [rows, packed_width(cols, bits)]
    levels: Any  # f32 [rows, 2**bits]
    shape: tuple
    cols: int
    bits: int


@dataclasses.dataclass(frozen=True)
class TopKWire:
    """Sparse wire buffer: (index, value) pairs for the k largest-|.| entries."""

    indices: Any  # int32 [batch?, k]
    values: Any  # f32 [batch?, k]
    shape: tuple


jax.tree_util.register_dataclass(
    QuantWire, data_fields=["packed", "lo", "scale"],
    meta_fields=["shape", "cols", "bits"])
jax.tree_util.register_dataclass(
    CodebookWire, data_fields=["packed", "levels"],
    meta_fields=["shape", "cols", "bits"])
jax.tree_util.register_dataclass(
    TopKWire, data_fields=["indices", "values"], meta_fields=["shape"])

_WIRE_TYPES = (QuantWire, CodebookWire, TopKWire)


def is_wire(x: Any) -> bool:
    return isinstance(x, _WIRE_TYPES)


# ---------------------------------------------------------------------------
# Row layout: identical grouping to the value-semantics compressors
# ---------------------------------------------------------------------------


def _row_layout(shape: tuple, rowwise: bool, batch_ndim: int) -> tuple[int, int]:
    """(rows, cols) of the 2-D view a leaf is quantized in.

    The first ``batch_ndim`` axes (the worker-stack K) always separate rows;
    within a batch element, ``rowwise`` quantizes per last-axis row when the
    element is >= 2-D, else the whole element is one row (matching
    ``quantize_linear``'s ``_row_reduce`` semantics).
    """
    batch = math.prod(shape[:batch_ndim]) if batch_ndim else 1
    inner = shape[batch_ndim:]
    if rowwise and len(inner) >= 2:
        return batch * math.prod(inner[:-1]), inner[-1]
    return batch, math.prod(inner) if inner else 1


# ---------------------------------------------------------------------------
# Leaf encode / decode
# ---------------------------------------------------------------------------


def _quant_codes_jnp(x2d: jax.Array, bits: int):
    """Elementwise-identical to ``kernels/ref.py:rowwise_quantize_ref``."""
    x32 = x2d.astype(jnp.float32)
    lo = jnp.min(x32, axis=1, keepdims=True)
    hi = jnp.max(x32, axis=1, keepdims=True)
    nlevels = (1 << bits) - 1
    scale = (hi - lo) / nlevels
    scale = jnp.where(scale <= 0.0, 1.0, scale)
    codes = jnp.round((x32 - lo) / scale).astype(jnp.uint8)
    return codes, lo, scale


def quant_encode(x: jax.Array, bits: int, rowwise: bool, *,
                 batch_ndim: int = 0, impl: str = "pallas") -> QuantWire:
    """Q: leaf -> wire (the paper's quantize point; Q1 worker-side, Q2 on
    the reduced shard)."""
    from repro.kernels.quantize import pack_codes

    assert bits <= 8, "codes are u8 on the wire"
    m, n = _row_layout(x.shape, rowwise, batch_ndim)
    x2d = x.reshape(m, n)
    if impl == "pallas":
        from repro.kernels.ops import quantize_rowwise

        _, codes, lo, scale = quantize_rowwise(x2d, bits=bits)
    else:
        codes, lo, scale = _quant_codes_jnp(x2d, bits)
    return QuantWire(packed=pack_codes(codes, bits), lo=lo, scale=scale,
                     shape=tuple(x.shape), cols=n, bits=bits)


def codebook_encode(x: jax.Array, bits: int, rowwise: bool, *,
                    batch_ndim: int = 0) -> CodebookWire:
    """Statistical (quantile-codebook) encode; codes + levels on the wire."""
    from repro.kernels.quantize import pack_codes

    assert bits <= 8, "codes are u8 on the wire"
    m, n = _row_layout(x.shape, rowwise, batch_ndim)
    x2d = x.reshape(m, n).astype(jnp.float32)
    nlevels = 1 << bits
    qs = (jnp.arange(nlevels, dtype=jnp.float32) + 0.5) / nlevels

    def encode_vec(v):  # [n] -> (levels [nlevels], codes u8 [n])
        levels = jnp.quantile(v, qs)  # sorted
        mids = 0.5 * (levels[1:] + levels[:-1])
        return levels, jnp.searchsorted(mids, v).astype(jnp.uint8)

    levels, codes = jax.vmap(encode_vec)(x2d)
    return CodebookWire(packed=pack_codes(codes, bits), levels=levels,
                        shape=tuple(x.shape), cols=n, bits=bits)


def topk_encode(x: jax.Array, frac: float, *, batch_ndim: int = 0) -> TopKWire:
    """Pack the k = ceil-round(frac * n) largest-|.| entries per batch element."""
    from repro.kernels.topk_pack import pack_topk

    inner = math.prod(x.shape[batch_ndim:])
    k = max(int(round(frac * inner)), 1)
    if batch_ndim:
        batch = math.prod(x.shape[:batch_ndim])
        idx, val = jax.vmap(lambda v: pack_topk(v, k))(x.reshape(batch, inner))
    else:
        idx, val = pack_topk(x.reshape(inner), k)
    return TopKWire(indices=idx, values=val, shape=tuple(x.shape))


def decode_leaf(w: Any, *, impl: str = "pallas") -> jax.Array:
    """The receiver: reconstruct a (f32) leaf from its wire buffers only."""
    from repro.kernels.quantize import unpack_codes
    from repro.kernels.topk_pack import unpack_topk

    if isinstance(w, QuantWire):
        codes = unpack_codes(w.packed, w.bits, w.cols)
        if impl == "pallas":
            from repro.kernels.ops import dequantize_rowwise

            vals = dequantize_rowwise(codes, w.lo, w.scale)
        else:
            vals = w.lo + codes.astype(jnp.float32) * w.scale
        return vals.reshape(w.shape)
    if isinstance(w, CodebookWire):
        codes = unpack_codes(w.packed, w.bits, w.cols)
        vals = jnp.take_along_axis(w.levels, codes.astype(jnp.int32), axis=1)
        return vals.reshape(w.shape)
    if isinstance(w, TopKWire):
        n = math.prod(w.shape)  # total elements
        if w.indices.ndim == 2:  # batched (K-stacked)
            batch = w.indices.shape[0]
            dense = jax.vmap(lambda i, v: unpack_topk(i, v, n // batch))(
                w.indices, w.values)
        else:
            dense = unpack_topk(w.indices, w.values, n)
        return dense.reshape(w.shape)
    raise TypeError(f"not a wire packet: {type(w)!r}")


# ---------------------------------------------------------------------------
# Tree-level helpers + byte accounting
# ---------------------------------------------------------------------------


def encode_leaf(x: jax.Array, cfg, *, batch_ndim: int = 0, impl: str | None = None):
    """Dispatch on the compression config (kind='none' passes through)."""
    if cfg.kind == "none":
        return x
    if cfg.kind == "topk":
        return topk_encode(x, cfg.topk_frac, batch_ndim=batch_ndim)
    if cfg.kind == "quant":
        if cfg.quant_mode == "statistical":
            return codebook_encode(x, cfg.bits, cfg.rowwise, batch_ndim=batch_ndim)
        return quant_encode(x, cfg.bits, cfg.rowwise, batch_ndim=batch_ndim,
                            impl=impl or cfg.wire_impl)
    raise ValueError(f"unknown compressor {cfg.kind!r}")


def encode_tree(tree: PyTree, cfg, *, batch_ndim: int = 0,
                impl: str | None = None) -> PyTree:
    return jax.tree.map(
        lambda x: encode_leaf(x, cfg, batch_ndim=batch_ndim, impl=impl), tree)


def decode_tree(wire_tree: PyTree, cfg, *, impl: str | None = None) -> PyTree:
    if cfg.kind == "none":
        return wire_tree
    return jax.tree.map(
        lambda w: decode_leaf(w, impl=impl or cfg.wire_impl),
        wire_tree, is_leaf=is_wire)


def buffer_bytes(x: Any) -> int:
    """Bytes of one buffer; works on arrays and ShapeDtypeStructs."""
    return int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize


def wire_tree_bytes(tree: PyTree) -> int:
    """Total bytes of every buffer in a (wire-packet or dense) pytree."""
    return sum(buffer_bytes(leaf) for leaf in jax.tree.leaves(tree))
