from repro.data.synthetic import DataConfig, MarkovStream, batches_for_round  # noqa: F401
