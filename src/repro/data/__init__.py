from repro.data.synthetic import (  # noqa: F401
    DataConfig,
    MarkovStream,
    batches_for_round,
    batches_for_span,
)
