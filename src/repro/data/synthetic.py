"""Deterministic synthetic LM data with per-worker shards.

A fixed random first-order Markov chain over the vocabulary with Zipfian
stationary structure: the data has real sequential signal (entropy well below
log V), so optimizer differences (AdamW vs Muon, K, H, compression) move the
loss the way they do on text. Each DiLoCo worker k draws from an independent
stream seeded by (seed, worker) — the paper's i.i.d. shard setting D_k.

Everything is derived from counters, so batches are reproducible, resumable
from a step index, and identical across hosts without any files.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 256
    seq_len: int = 128
    batch_per_worker: int = 8
    n_workers: int = 1
    seed: int = 0       # sampling stream (train vs held-out eval use different seeds)
    table_seed: int = 0  # the "language" (transition table) — shared across streams
    branching: int = 8  # successors per state: entropy ~= log2(branching) bits


def _transition_table(cfg: DataConfig) -> np.ndarray:
    """[vocab, branching] successor table + Zipf-weighted start distribution.

    Keyed by ``table_seed`` (not ``seed``) so train and eval streams sample
    the SAME chain with disjoint randomness — held-out eval, same language."""
    rng = np.random.default_rng(cfg.table_seed + 1337)
    return rng.integers(0, cfg.vocab, size=(cfg.vocab, cfg.branching), dtype=np.int32)


@dataclasses.dataclass
class MarkovStream:
    cfg: DataConfig

    def __post_init__(self):
        self.table = jnp.asarray(_transition_table(self.cfg))
        zipf = 1.0 / (np.arange(1, self.cfg.vocab + 1) ** 1.2)
        self.start_logits = jnp.asarray(np.log(zipf / zipf.sum()), jnp.float32)
        # one compiled sampler per n_steps (jitted: a whole round's batches
        # are generated in a single dispatch instead of H python-level calls)
        self._stacked_fns: dict[int, callable] = {}

    def _batch_toks(self, step) -> jax.Array:
        """[K, B, S+1] token sample for one global step (traced-step safe)."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        keys = jax.random.split(key, cfg.n_workers)
        return jax.vmap(lambda k: self._sample(k, cfg.batch_per_worker, cfg.seq_len + 1))(keys)

    def batch(self, step: int) -> dict:
        """Batch for one global step: leaves [K, B, S] (+labels)."""
        toks = self._batch_toks(step)
        return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}

    def batch_stack(self, start_step: int, n_steps: int) -> dict:
        """``n_steps`` consecutive batches in ONE compiled call: [n, K, B, S].

        Bitwise-identical to stacking ``batch(start_step + h)`` for h in
        range(n_steps) — the per-step threefry fold-in and per-worker sampling
        are the same ops under an extra vmap — but built device-side in a
        single dispatch, so the engine's scan input no longer costs H
        host-level trace/dispatch round-trips per round.
        """
        fn = self._stacked_fns.get(n_steps)
        if fn is None:
            def stacked(start):
                steps = start + jnp.arange(n_steps)
                return jax.vmap(self._batch_toks)(steps)

            fn = self._stacked_fns[n_steps] = jax.jit(stacked)
        toks = fn(jnp.asarray(start_step, jnp.int32))
        return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}

    def _sample(self, key: jax.Array, batch: int, length: int) -> jax.Array:
        cfg = self.cfg
        k0, k1 = jax.random.split(key)
        state = jax.random.categorical(k0, self.start_logits, shape=(batch,))

        def step_fn(state, k):
            choice = jax.random.randint(k, (batch,), 0, cfg.branching)
            nxt = self.table[state, choice]
            return nxt, state

        ks = jax.random.split(k1, length)
        _, toks = jax.lax.scan(step_fn, state, ks)
        return toks.T.astype(jnp.int32)  # [batch, length]

    def entropy_floor_nats(self) -> float:
        """Per-token entropy of the chain (the achievable loss floor)."""
        return float(np.log(self.cfg.branching))


def batches_for_round(stream: MarkovStream, round_idx: int, sync_interval: int) -> dict:
    """Stacked batches for one DiLoCo round: leaves [H, K, B, S].

    Generated in one compiled call (:meth:`MarkovStream.batch_stack`) rather
    than H sequential ``stream.batch`` host dispatches."""
    return stream.batch_stack(round_idx * sync_interval, sync_interval)


def batches_for_span(stream: MarkovStream, round_idx: int, sync_interval: int,
                     n_rounds: int) -> dict:
    """Round-stacked batches for ``n_rounds`` consecutive rounds:
    leaves [R, H, K, B, S] — the superstep executor's input.

    One compiled ``batch_stack`` call for all R*H steps, then a reshape of
    the leading axis; bitwise-identical to stacking
    ``batches_for_round(stream, round_idx + i, sync_interval)`` for i in
    range(n_rounds)."""
    flat = stream.batch_stack(round_idx * sync_interval, n_rounds * sync_interval)
    return jax.tree.map(
        lambda x: x.reshape(n_rounds, sync_interval, *x.shape[1:]), flat)
