"""Top-k (index, value) wire packing for the sparse pseudogradient collective.

The paper's top-k compressor ships the k largest-|.| entries of each worker
delta as explicit (index, value) pairs; the all-gather + local-reduce
collective then scatters every worker's pairs back into a dense accumulator
(§2 "Collectives for compressed communication"). These are the pack/unpack
halves of that wire format. They are XLA gather/scatter ops rather than a
Pallas kernel: the access pattern is data-dependent and memory-bound, so a
hand-written kernel has nothing to fuse — the wire layout (int32 index +
fp32 value per kept entry) is the point.

``pack_topk(x, k)`` is value-equivalent to keeping the same k entries of
``repro.core.compression.topk_sparsify`` (both rank by |.| via
``jax.lax.top_k``, so tie-breaking is identical).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pack_topk(x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """[n] -> (indices i32 [k], values [k]): the k largest-|.| entries."""
    flat = x.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return idx.astype(jnp.int32), flat[idx]


def unpack_topk(indices: jax.Array, values: jax.Array, n: int) -> jax.Array:
    """(indices [k], values [k]) -> dense [n] with zeros elsewhere.

    ``jax.lax.top_k`` indices are unique, so the scatter has no collisions.
    """
    return jnp.zeros((n,), values.dtype).at[indices].set(values)
