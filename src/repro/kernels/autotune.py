"""Kernel autotune tables: best-known block configs per (shape, dtype, backend).

Every block-size knob the kernels expose — attention ``attn_block_q`` /
``attn_block_kv`` / ``blockwise_threshold``, the wire quantizer's
``block_rows``, the Newton–Schulz matmul ``block`` — has so far been a
hand-picked constant. This module gives them the maxtext-style treatment:
a committed JSON table maps ``kernel/shape/dtype/backend`` keys to the
best-known config, a sweep harness refreshes it, and the call sites
(:func:`tuned_model_config` for the ModelConfig knobs,
:mod:`repro.kernels.ops` for the per-call kernel knobs) consult it by
default with the current constants as fallback — a missing table, a missing
entry, or ``configure(enabled=False)`` all reproduce the pre-autotune
behavior exactly.

**The bitwise-inert contract.** The training pins reference losses
(tests/test_parity.py), so the table may only ever change *scheduling*,
never arithmetic. The sweep enforces that mechanically: a candidate config
is eligible only if its output is bit-for-bit identical to the default
config's output on the swept shape (pure tiling knobs — e.g. quantize
``block_rows`` retiles independent rows, attention ``attn_block_q`` retiles
independent query rows). Knobs whose value changes reduction order
(``attn_block_kv`` across kv blocks, NS matmul ``block`` when it splits the
contraction) simply fail the gate and keep their defaults, and knobs that
change semantics outright (``ns_period`` orthogonalizes less often) are not
swept at all. ``tests/test_autotune.py`` re-verifies the committed entries
on the parity path.

Key layout::

    {
      "attention/64x9x3x64/float32/cpu":  {"config": {"attn_block_q": 64, ...},
                                           "evidence": {"speedup": 1.07, ...}},
      "quantize/128x256x4/float32/cpu":   {"config": {"block_rows": 32}, ...},
      "ns/64x64/float32/cpu":             {"config": {"block": 128}, ...}
    }

Refresh with::

    PYTHONPATH=src python -m repro.kernels.autotune --suite reduced \
        --out src/repro/kernels/autotune_table.json
"""
from __future__ import annotations

import json
import os
from contextlib import contextmanager
from contextvars import ContextVar
from functools import lru_cache
from typing import Any

DEFAULT_TABLE_PATH = os.path.join(os.path.dirname(__file__),
                                  "autotune_table.json")

# Candidate grids the sweep walks (clamped to the shape where needed).
ATTN_BLOCK_Q_CANDIDATES = (32, 64, 128, 256, 512)
ATTN_BLOCK_KV_CANDIDATES = (64, 128, 256, 512, 1024)
QUANTIZE_BLOCK_ROWS_CANDIDATES = (4, 8, 16, 32, 64)
NS_BLOCK_CANDIDATES = (32, 64, 128, 256)


def autotune_key(kernel: str, shape: tuple, dtype: str, backend: str) -> str:
    """Canonical table key: ``kernel/shape/dtype/backend``.

    The shape component joins the integer dims with 'x', so the key is a
    stable, human-diffable string (committed JSON must review cleanly) and
    hashing/equality are plain string ops.
    """
    dims = "x".join(str(int(d)) for d in shape)
    return f"{kernel}/{dims}/{dtype}/{backend}"


def _backend() -> str:
    import jax

    return jax.default_backend()


class AutotuneTable:
    """In-memory view of one autotune JSON table."""

    def __init__(self, entries: dict[str, dict] | None = None,
                 path: str | None = None):
        self.entries = dict(entries or {})
        self.path = path

    @classmethod
    def load(cls, path: str | None = None) -> "AutotuneTable":
        path = path or DEFAULT_TABLE_PATH
        entries: dict[str, dict] = {}
        if os.path.exists(path):
            with open(path) as f:
                entries = json.load(f)
        return cls(entries, path=path)

    def lookup(self, kernel: str, shape: tuple, dtype: str,
               backend: str | None = None) -> dict | None:
        """Best-known config dict for the key, or None (caller's default)."""
        key = autotune_key(kernel, shape, dtype, backend or _backend())
        ent = self.entries.get(key)
        return None if ent is None else dict(ent["config"])

    def record(self, kernel: str, shape: tuple, dtype: str, backend: str,
               config: dict, evidence: dict | None = None) -> str:
        key = autotune_key(kernel, shape, dtype, backend)
        self.entries[key] = {"config": config, "evidence": evidence or {}}
        return key

    def save(self, path: str | None = None) -> str:
        path = path or self.path or DEFAULT_TABLE_PATH
        with open(path, "w") as f:
            json.dump(self.entries, f, indent=1, sort_keys=True)
            f.write("\n")
        return path


@lru_cache(maxsize=8)
def _cached_table(path: str) -> AutotuneTable:
    return AutotuneTable.load(path)


# (enabled, table_path): the process default consults the committed table;
# a ContextVar so tests and the sweep itself can scope overrides.
_active: ContextVar[tuple[bool, str | None]] = ContextVar(
    "autotune_active", default=(True, None))


def configure(enabled: bool = True, table_path: str | None = None) -> None:
    """Set the process-wide autotune routing (the CLI --autotune flags)."""
    _active.set((enabled, table_path))
    active_table.cache_clear()


@contextmanager
def autotune_scope(enabled: bool = True, table_path: str | None = None):
    """Scoped override of the active table (tests / sweep verification)."""
    tok = _active.set((enabled, table_path))
    active_table.cache_clear()
    try:
        yield
    finally:
        _active.reset(tok)
        active_table.cache_clear()


@lru_cache(maxsize=1)
def _active_cached(enabled: bool, path: str | None) -> AutotuneTable | None:
    if not enabled:
        return None
    return _cached_table(path or DEFAULT_TABLE_PATH)


def active_table() -> AutotuneTable | None:
    """The table the call sites consult, or None when autotune is off."""
    enabled, path = _active.get()
    return _active_cached(enabled, path)


active_table.cache_clear = _active_cached.cache_clear  # type: ignore[attr-defined]


# ---------------------------------------------------------------------------
# Call-site lookups (each returns the caller's fallback on any miss)
# ---------------------------------------------------------------------------


def attention_config(seq_len: int, n_heads: int, n_kv_heads: int,
                     head_dim: int, dtype: str,
                     backend: str | None = None) -> dict:
    """Tuned ModelConfig attention knobs for one shape, or {} on miss."""
    table = active_table()
    if table is None or not seq_len:
        return {}
    cfg = table.lookup("attention", (seq_len, n_heads, n_kv_heads, head_dim),
                       dtype, backend)
    return cfg or {}


def quantize_block_rows(m: int, n: int, bits: int, dtype: str,
                        backend: str | None = None) -> int | None:
    table = active_table()
    if table is None:
        return None
    cfg = table.lookup("quantize", (m, n, bits), dtype, backend)
    return None if cfg is None else int(cfg["block_rows"])


def ns_block(m: int, n: int, dtype: str, backend: str | None = None) -> int | None:
    table = active_table()
    if table is None:
        return None
    cfg = table.lookup("ns", (m, n), dtype, backend)
    return None if cfg is None else int(cfg["block"])


def tuned_model_config(cfg, seq_len: int | None = None,
                       backend: str | None = None):
    """ModelConfig with the table's attention knobs applied (fallback: cfg).

    The committed constants (``attn_block_q=512`` etc.) remain the defaults;
    only knobs present in the matching table entry are replaced. Entries are
    recorded under the (seq_len, n_heads, n_kv_heads, head_dim) shape key in
    the model's compute dtype.
    """
    S = int(seq_len or cfg.max_seq_len or 0)
    tuned = attention_config(S, cfg.n_heads, cfg.n_kv_heads or cfg.n_heads,
                             cfg.hd, str(cfg.compute_dtype), backend)
    tuned = {k: v for k, v in tuned.items()
             if k in ("attn_block_q", "attn_block_kv", "blockwise_threshold")}
    return cfg.replace(**tuned) if tuned else cfg


def autotune_evidence(cfg, seq_len: int | None = None) -> dict:
    """Evidence block for the dry-run records: what the table resolved."""
    enabled, path = _active.get()
    table = active_table()
    tuned = tuned_model_config(cfg, seq_len) if table is not None else cfg
    hits = {k: getattr(tuned, k) for k in
            ("attn_block_q", "attn_block_kv", "blockwise_threshold")
            if getattr(tuned, k) != getattr(cfg, k)}
    return {
        "enabled": enabled,
        "table": (path or "builtin") if enabled else None,
        "entries": 0 if table is None else len(table.entries),
        "tuned": hits,  # {} = every knob fell back to the committed constants
    }


# ---------------------------------------------------------------------------
# Sweep harness
# ---------------------------------------------------------------------------


def _time_best(fn, reps: int = 3) -> float:
    """Best-of-reps wall time of a blocking call (one warmup for compile)."""
    import time

    import jax

    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _bitwise_equal(a, b) -> bool:
    import jax
    import numpy as np

    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def _sweep(run, default_config: dict, candidates: list[dict],
           reps: int = 3) -> tuple[dict, dict]:
    """Generic sweep: time every candidate, keep the fastest whose output is
    BITWISE identical to the default config's output. Returns
    ``(best_config, evidence)`` — best_config == default_config when nothing
    inert beats it."""
    ref = run(**default_config)
    t_default = _time_best(lambda: run(**default_config), reps=reps)
    best, t_best = dict(default_config), t_default
    rejected = 0
    for cand in candidates:
        if cand == default_config:
            continue
        out = run(**cand)
        if not _bitwise_equal(ref, out):
            rejected += 1  # not tiling-pure on this shape: ineligible
            continue
        t = _time_best(lambda: run(**cand), reps=reps)
        if t < t_best:
            best, t_best = dict(cand), t
    evidence = {
        "default_s": t_default, "best_s": t_best,
        "speedup": (t_default / t_best) if t_best > 0 else 1.0,
        "candidates": len(candidates), "rejected_not_bitwise": rejected,
        "verified_bitwise": True,
    }
    return best, evidence


def sweep_attention(table: AutotuneTable, seq_len: int, n_heads: int,
                    n_kv_heads: int, head_dim: int, *, batch: int = 2,
                    attn_impl: str = "xla", dtype: str = "float32",
                    reps: int = 3, seed: int = 0) -> str:
    """Sweep the ModelConfig attention knobs for one (S, H, KV, hd) shape."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_attention import clamp_block
    from repro.models.attention import attend, init_attention
    from repro.models.common import ModelConfig

    base = ModelConfig(
        name=f"autotune-s{seq_len}", vocab=64, d_model=n_heads * head_dim,
        n_layers=1, n_heads=n_heads, n_kv_heads=n_kv_heads,
        max_seq_len=seq_len, attn_impl=attn_impl, dtype=dtype)
    rng = jax.random.PRNGKey(seed)
    p = init_attention(rng, base)
    x = jax.random.normal(jax.random.fold_in(rng, 1),
                          (batch, seq_len, base.d_model), dtype)
    positions = jnp.arange(seq_len)

    def run(attn_block_q, attn_block_kv, blockwise_threshold):
        cfg = base.replace(attn_block_q=clamp_block(attn_block_q, seq_len),
                           attn_block_kv=clamp_block(attn_block_kv, seq_len),
                           blockwise_threshold=blockwise_threshold)
        return jax.jit(lambda pp, xx: attend(pp, cfg, xx, positions))(p, x)

    default = {"attn_block_q": clamp_block(512, seq_len),
               "attn_block_kv": clamp_block(1024, seq_len),
               "blockwise_threshold": 4096}
    cands = [{"attn_block_q": clamp_block(bq, seq_len),
              "attn_block_kv": clamp_block(bkv, seq_len),
              "blockwise_threshold": 4096}
             for bq in ATTN_BLOCK_Q_CANDIDATES
             for bkv in ATTN_BLOCK_KV_CANDIDATES]
    best, ev = _sweep(run, default, cands, reps=reps)
    return table.record("attention", (seq_len, n_heads, n_kv_heads, head_dim),
                        dtype, _backend(), best, ev)


def sweep_quantize(table: AutotuneTable, m: int, n: int, *, bits: int = 4,
                   dtype: str = "float32", reps: int = 3, seed: int = 0) -> str:
    """Sweep the rowwise-quantizer block_rows for one [m, n] wire shape."""
    import jax

    from repro.kernels import ops

    x = jax.random.normal(jax.random.PRNGKey(seed), (m, n), dtype)

    def run(block_rows):
        return ops.quantize_rowwise(x, bits=bits, block_rows=block_rows)

    best, ev = _sweep(run, {"block_rows": 8},
                      [{"block_rows": b} for b in QUANTIZE_BLOCK_ROWS_CANDIDATES
                       if b <= m], reps=reps)
    return table.record("quantize", (m, n, bits), dtype, _backend(), best, ev)


def sweep_ns(table: AutotuneTable, m: int, n: int, *, dtype: str = "float32",
             reps: int = 3, seed: int = 0) -> str:
    """Sweep the Newton–Schulz matmul block for one [m, n] momentum shape."""
    import jax

    from repro.kernels import ops

    g = jax.random.normal(jax.random.PRNGKey(seed), (m, n), dtype)

    def run(block):
        return ops.ns_orthogonalize(g, block=block)

    best, ev = _sweep(run, {"block": 128},
                      [{"block": b} for b in NS_BLOCK_CANDIDATES], reps=reps)
    return table.record("ns", (m, n), dtype, _backend(), best, ev)


# Shapes per suite, measured off the actual reduced-path call sites
# (instrumented ops.* on a reduced smollm run): 'reduced' covers the CPU
# parity/CI path — attention (S=128, 4 heads / 1 kv head, hd=64), the
# K-folded wire row layouts the rowwise quantizer sees, and the per-layer
# weight stacks Muon orthogonalizes; 'extended' adds the mid-size shapes the
# benchmarks exercise.
SWEEP_SUITES: dict[str, dict[str, list[tuple]]] = {
    "reduced": {
        "attention": [(64, 4, 1, 64), (128, 4, 1, 64), (128, 4, 4, 64)],
        "quantize": [(512, 64, 4), (512, 256, 4), (512, 512, 4),
                     (1024, 64, 4), (1024, 256, 4), (1024, 512, 4),
                     (2048, 256, 4)],
        "ns": [(256, 64), (256, 256), (256, 512), (512, 256)],
    },
    "extended": {
        "attention": [(256, 4, 4, 64), (256, 8, 8, 32)],
        "quantize": [(1024, 1024, 4), (4096, 512, 4)],
        "ns": [(1024, 256), (1024, 1024)],
    },
}


def run_sweeps(suite: str = "reduced", out: str | None = None,
               reps: int = 3, verbose: bool = True) -> AutotuneTable:
    """Run every sweep in a suite and merge results into the table at ``out``."""
    shapes = SWEEP_SUITES[suite]
    table = AutotuneTable.load(out)
    with autotune_scope(enabled=False):  # sweeps must measure raw defaults
        for s in shapes["attention"]:
            key = sweep_attention(table, *s, reps=reps)
            if verbose:
                print(f"{key}: {table.entries[key]['config']} "
                      f"(x{table.entries[key]['evidence']['speedup']:.2f})")
        for s in shapes["quantize"]:
            key = sweep_quantize(table, s[0], s[1], bits=s[2], reps=reps)
            if verbose:
                print(f"{key}: {table.entries[key]['config']} "
                      f"(x{table.entries[key]['evidence']['speedup']:.2f})")
        for s in shapes["ns"]:
            key = sweep_ns(table, *s, reps=reps)
            if verbose:
                print(f"{key}: {table.entries[key]['config']} "
                      f"(x{table.entries[key]['evidence']['speedup']:.2f})")
    table.save(out)
    return table


def build_parser():
    import argparse

    ap = argparse.ArgumentParser(
        description="sweep the kernel block-size knobs and refresh the "
                    "committed autotune table")
    ap.add_argument("--suite", default="reduced", choices=list(SWEEP_SUITES),
                    help="which shape set to sweep")
    ap.add_argument("--out", default=DEFAULT_TABLE_PATH,
                    help="table JSON to merge results into")
    ap.add_argument("--reps", type=int, default=3,
                    help="timing repetitions per candidate (best-of)")
    return ap


def main() -> None:
    args = build_parser().parse_args()
    table = run_sweeps(args.suite, out=args.out, reps=args.reps)
    print(f"wrote {len(table.entries)} entries to {args.out}")


if __name__ == "__main__":
    main()
