"""Blocked Pallas flash-attention — the training-hot-path kernel.

Every MuLoCo round runs K workers x H inner steps of transformer
forward/backward, so attention dominates the engine's roofline at production
sequence lengths. This kernel is the fused-SRAM answer (Dao et al., 2022,
lowered TPU-style a la the maxtext block kernels), following the same
pattern the repo already uses for Newton-Schulz (``kernels/matmul.py``) and
quantization (``kernels/quantize.py``):

* **GQA-native layout**: queries travel as ``[B*KV, S, G, hd]`` (G = H/KV
  query heads per KV head), K/V as ``[B*KV, S, hd]`` — each K/V tile is
  loaded into VMEM once per q block and shared by all G query heads, never
  materialized H/KV times.
* **Online softmax**: fp32 ``m``/``l``/``acc`` accumulators live in VMEM
  scratch across the kv-block sweep; the epilogue normalizes once and also
  emits the per-row logsumexp for the backward pass.
* **Full-block skipping**: the grid is built from an explicit *visit
  schedule* (:func:`attention_schedule`) carried in via scalar prefetch —
  kv blocks entirely above the causal diagonal or outside the sliding
  window are **never visited** (not merely masked), so the causal grid does
  ~half the work and a sliding-window grid O(window/S) of it. The schedule
  is plain Python over static shapes, so tests assert the visit count on
  the grid itself, not on timing.
* **Flash-style custom VJP**: the backward recomputes per-block
  probabilities from the saved logsumexp (O(S) residuals: q, k, v, o, lse —
  never an [S, S] tensor), matching the ``jax.checkpoint`` contract of the
  XLA blockwise fallback. Two kernels: a q-major sweep for dq and a
  kv-major sweep for dk/dv, both on the same skip schedule.

Like the other kernels, this runs ``interpret=True`` off-TPU (the CPU test
target). On multi-device meshes the call sites consult the kernel
partitioning context (:mod:`repro.kernels.partition`): when the StepPlan
machinery routes a mesh, the custom-VJP call — forward and both backward
sweeps — is wrapped in ``shard_map`` over the fused [B*KV, ...] batch-head
axis (:func:`flash_specs`), so ``attn_impl='pallas'`` lowers under GSPMD
with bitwise-identical outputs. The visit schedule stays a closed-over
trace constant (replicated); the paged decode kernel co-shards the page
table with its batch-slot axis against a replicated KV pool
(:func:`paged_specs`).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import PartitionSpec as P

from repro.kernels.partition import (
    KernelPartitioning,
    active_partitioning,
    axes_for,
    shard_wrap,
)

NEG_INF = -2.0e38
DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_KV = 1024


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# The visit schedule: which (q-block, kv-block) pairs the grid executes.
# ---------------------------------------------------------------------------


def _block_visited(qi: int, kj: int, block_q: int, block_kv: int,
                   causal: bool, window: int) -> bool:
    """True when block (qi, kj) contains any unmasked (row, col) pair."""
    if causal and kj * block_kv > qi * block_q + block_q - 1:
        return False  # entirely above the diagonal
    if window and (qi * block_q) - (kj * block_kv + block_kv - 1) >= window:
        return False  # entirely left of the sliding window
    return True


def attention_schedule(nq: int, nkv: int, block_q: int, block_kv: int,
                       causal: bool, window: int,
                       skip: bool = True) -> list[tuple[int, int]]:
    """q-major list of visited (q-block, kv-block) pairs — this IS the grid.

    ``skip=False`` returns the full nq x nkv sweep (the no-skip oracle the
    block-skip tests compare against). For causal attention with
    ``block_q <= block_kv`` the visited count is at most
    ``nq*nkv/2 + nq`` — asserted here so every kernel launch proves its own
    grid bound.
    """
    pairs = [(qi, kj) for qi in range(nq) for kj in range(nkv)
             if not skip or _block_visited(qi, kj, block_q, block_kv, causal, window)]
    if skip and causal and not window and block_q <= block_kv:
        assert len(pairs) <= nq * nkv // 2 + nq, (len(pairs), nq, nkv)
    return pairs


def visited_kv_range(qi: int, nkv: int, block_q: int, block_kv: int,
                     causal: bool, window: int) -> tuple[int, int]:
    """Contiguous [lo, hi) kv-block range q-block ``qi`` must visit.

    Causal masking bounds ``hi`` (diagonal), the sliding window bounds
    ``lo``; both are static, so the XLA blockwise fallback scans exactly
    this range per q block.
    """
    visited = [kj for kj in range(nkv)
               if _block_visited(qi, kj, block_q, block_kv, causal, window)]
    assert visited, (qi, nkv, block_q, block_kv, causal, window)
    assert visited == list(range(visited[0], visited[-1] + 1)), "range not contiguous"
    return visited[0], visited[-1] + 1


def clamp_block(block: int, S: int) -> int:
    """A divisor of S that is <= block, found by halving — terminates at
    b=1 for any S (S % 1 == 0), so odd sequence lengths fall back to
    unit blocks rather than failing."""
    b = max(1, min(block, S))
    while S % b:
        b //= 2
    return b


def visited_fraction(S: int, block_q: int, block_kv: int,
                     causal: bool, window: int) -> float:
    """Fraction of the nq x nkv block grid the schedule visits — the
    roofline's attention-flops discount for both attention impls."""
    bq, bkv = clamp_block(block_q, S), clamp_block(block_kv, S)
    nq, nkv = S // bq, S // bkv
    return len(attention_schedule(nq, nkv, bq, bkv, causal, window)) / (nq * nkv)


@functools.lru_cache(maxsize=None)
def _sched_array(nq: int, nkv: int, block_q: int, block_kv: int,
                 causal: bool, window: int, kv_major: bool,
                 skip: bool) -> np.ndarray:
    """int32 [n, 4] rows (qi, kj, first, last) for the scalar-prefetch grid.

    q-major order for the forward/dq sweeps (first/last flag the edges of
    each q block's kv run); kv-major for the dk/dv sweep (flags per kv
    block's q run).
    """
    pairs = attention_schedule(nq, nkv, block_q, block_kv, causal, window,
                               skip=skip)
    group = 1 if kv_major else 0
    if kv_major:
        pairs = sorted(pairs, key=lambda p: (p[1], p[0]))
    sched = np.zeros((len(pairs), 4), np.int32)
    for g, (qi, kj) in enumerate(pairs):
        sched[g, 0], sched[g, 1] = qi, kj
        sched[g, 2] = 1 if (g == 0 or pairs[g][group] != pairs[g - 1][group]) else 0
        sched[g, 3] = 1 if (g == len(pairs) - 1
                            or pairs[g][group] != pairs[g + 1][group]) else 0
    return sched


# ---------------------------------------------------------------------------
# Kernels (q [BKV, S, G, hd]; k/v [BKV, S, hd]; fp32 accumulation in VMEM)
# ---------------------------------------------------------------------------


def _mask_and_positions(qi, kj, bq, bkv, G, causal, window):
    """Unmasked-entry predicate for the [bq*G, bkv] score tile."""
    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq * G, bkv), 0) // G
    cols = kj * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq * G, bkv), 1)
    mask = jnp.ones((bq * G, bkv), bool)
    if causal:
        mask &= rows >= cols
    if window:
        mask &= rows - cols < window
    return mask


def _scores(q_ref, k_ref, bq, G, hd, scale):
    q = q_ref[0].reshape(bq * G, hd).astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    return jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32) * scale


def _fwd_kernel(sched_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr, *, bq, bkv, G, hd, causal, window, scale):
    g = pl.program_id(1)
    qi, kj = sched_ref[g, 0], sched_ref[g, 1]

    @pl.when(sched_ref[g, 2] == 1)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    s = _scores(q_ref, k_ref, bq, G, hd, scale)
    mask = _mask_and_positions(qi, kj, bq, bkv, G, causal, window)
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # explicit mask (not just exp of NEG_INF): keeps fully-masked blocks at
    # exactly zero contribution, which is what makes skipped == visited
    # bitwise (tests/test_attention.py::test_block_skipping_is_exact)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc_new = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(sched_ref[g, 3] == 1)
    def _epilogue():
        l = jnp.maximum(l_new, 1e-30)
        o_ref[0] = (acc_new / l).reshape(bq, G, hd).astype(o_ref.dtype)
        lse_ref[0] = (m_new + jnp.log(l)).reshape(bq, G)


def _probs(sched_ref, q_ref, k_ref, lse_ref, g, *, bq, bkv, G, hd,
           causal, window, scale):
    """Recompute the [bq*G, bkv] probability tile from the saved logsumexp."""
    qi, kj = sched_ref[g, 0], sched_ref[g, 1]
    s = _scores(q_ref, k_ref, bq, G, hd, scale)
    mask = _mask_and_positions(qi, kj, bq, bkv, G, causal, window)
    lse = lse_ref[0].reshape(bq * G, 1)
    return jnp.where(mask, jnp.exp(s - lse), 0.0)


def _dq_kernel(sched_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
               dq_ref, dq_scr, *, bq, bkv, G, hd, causal, window, scale):
    g = pl.program_id(1)

    @pl.when(sched_ref[g, 2] == 1)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    p = _probs(sched_ref, q_ref, k_ref, lse_ref, g, bq=bq, bkv=bkv, G=G,
               hd=hd, causal=causal, window=window, scale=scale)
    do = do_ref[0].reshape(bq * G, hd).astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - dl_ref[0].reshape(bq * G, 1))
    k = k_ref[0].astype(jnp.float32)
    dq_scr[...] += scale * jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(sched_ref[g, 3] == 1)
    def _epilogue():
        dq_ref[0] = dq_scr[...].reshape(bq, G, hd).astype(dq_ref.dtype)


def _dkv_kernel(sched_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, bq, bkv, G, hd,
                causal, window, scale):
    g = pl.program_id(1)

    @pl.when(sched_ref[g, 2] == 1)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    p = _probs(sched_ref, q_ref, k_ref, lse_ref, g, bq=bq, bkv=bkv, G=G,
               hd=hd, causal=causal, window=window, scale=scale)
    do = do_ref[0].reshape(bq * G, hd).astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - dl_ref[0].reshape(bq * G, 1))
    q = q_ref[0].reshape(bq * G, hd).astype(jnp.float32)
    dk_scr[...] += scale * jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(sched_ref[g, 3] == 1)
    def _epilogue():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers
# ---------------------------------------------------------------------------


def _grid_spec(sched: np.ndarray, BKV: int, bq: int, bkv: int, G: int,
               hd: int, extra_in: list, extra_out: list, scratch: list):
    """PrefetchScalarGridSpec shared by all three sweeps: the schedule rides
    as scalar prefetch and the index maps read (qi, kj) off it."""
    q_spec = pl.BlockSpec((1, bq, G, hd), lambda b, g, s: (b, s[g, 0], 0, 0))
    kv_spec = pl.BlockSpec((1, bkv, hd), lambda b, g, s: (b, s[g, 1], 0))
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BKV, sched.shape[0]),
        in_specs=[q_spec, kv_spec, kv_spec, *extra_in],
        out_specs=extra_out,
        scratch_shapes=scratch,
    )


def _fwd(q, k, v, *, causal, window, bq, bkv, scale, interpret, skip):
    BKV, S, G, hd = q.shape
    nq, nkv = S // bq, S // bkv
    sched = _sched_array(nq, nkv, bq, bkv, causal, window, False, skip)
    kernel = functools.partial(_fwd_kernel, bq=bq, bkv=bkv, G=G, hd=hd,
                               causal=causal, window=window, scale=scale)
    q_out = pl.BlockSpec((1, bq, G, hd), lambda b, g, s: (b, s[g, 0], 0, 0))
    lse_out = pl.BlockSpec((1, bq, G), lambda b, g, s: (b, s[g, 0], 0))
    grid_spec = _grid_spec(
        sched, BKV, bq, bkv, G, hd, extra_in=[],
        extra_out=[q_out, lse_out],
        scratch=[pltpu.VMEM((bq * G, 1), jnp.float32),
                 pltpu.VMEM((bq * G, 1), jnp.float32),
                 pltpu.VMEM((bq * G, hd), jnp.float32)])
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((BKV, S, G, hd), q.dtype),
                   jax.ShapeDtypeStruct((BKV, S, G), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(sched), q, k, v)


def _bwd(q, k, v, o, lse, do, *, causal, window, bq, bkv, scale, interpret,
         skip):
    BKV, S, G, hd = q.shape
    nq, nkv = S // bq, S // bkv
    # dl = rowsum(do * o): the only extra residual the flash backward needs
    dl = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    do_spec = pl.BlockSpec((1, bq, G, hd), lambda b, g, s: (b, s[g, 0], 0, 0))
    row_spec = pl.BlockSpec((1, bq, G), lambda b, g, s: (b, s[g, 0], 0))
    kv_out = pl.BlockSpec((1, bkv, hd), lambda b, g, s: (b, s[g, 1], 0))
    kw = dict(bq=bq, bkv=bkv, G=G, hd=hd, causal=causal, window=window,
              scale=scale)

    sched_q = _sched_array(nq, nkv, bq, bkv, causal, window, False, skip)
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **kw),
        grid_spec=_grid_spec(
            sched_q, BKV, bq, bkv, G, hd,
            extra_in=[do_spec, row_spec, row_spec],
            extra_out=[do_spec],
            scratch=[pltpu.VMEM((bq * G, hd), jnp.float32)]),
        out_shape=[jax.ShapeDtypeStruct((BKV, S, G, hd), q.dtype)],
        interpret=interpret,
    )(jnp.asarray(sched_q), q, k, v, do, lse, dl)[0]

    sched_kv = _sched_array(nq, nkv, bq, bkv, causal, window, True, skip)
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, **kw),
        grid_spec=_grid_spec(
            sched_kv, BKV, bq, bkv, G, hd,
            extra_in=[do_spec, row_spec, row_spec],
            extra_out=[kv_out, kv_out],
            scratch=[pltpu.VMEM((bkv, hd), jnp.float32),
                     pltpu.VMEM((bkv, hd), jnp.float32)]),
        out_shape=[jax.ShapeDtypeStruct((BKV, S, hd), k.dtype),
                   jax.ShapeDtypeStruct((BKV, S, hd), v.dtype)],
        interpret=interpret,
    )(jnp.asarray(sched_kv), q, k, v, do, lse, dl)
    return dq, dk, dv


@functools.lru_cache(maxsize=None)
def _flash_fn(causal: bool, window: int, bq: int, bkv: int, scale: float,
              interpret: bool, skip: bool):
    """custom_vjp'd [BKV, S, G, hd] attention for one static config."""

    @jax.custom_vjp
    def fn(q, k, v):
        return _fwd(q, k, v, causal=causal, window=window, bq=bq, bkv=bkv,
                    scale=scale, interpret=interpret, skip=skip)[0]

    def fwd(q, k, v):
        o, lse = _fwd(q, k, v, causal=causal, window=window, bq=bq, bkv=bkv,
                      scale=scale, interpret=interpret, skip=skip)
        return o, (q, k, v, o, lse)

    def bwd(res, do):
        q, k, v, o, lse = res
        dq, dk, dv = _bwd(q, k, v, o, lse, do, causal=causal, window=window,
                          bq=bq, bkv=bkv, scale=scale, interpret=interpret,
                          skip=skip)
        return dq, dk, dv

    fn.defvjp(fwd, bwd)
    return fn


# ---------------------------------------------------------------------------
# shard_map specs (consulted when the StepPlan machinery routes a mesh)
# ---------------------------------------------------------------------------


def flash_specs(part: KernelPartitioning, lead: int) -> tuple[P, P]:
    """(q_spec [lead, S, G, hd], kv_spec [lead, S, hd]) for the fused
    batch-head axis. ``lead = B*KV`` is B-major, so the ('data', 'model')
    preference aligns batch with 'data' and kv-heads with 'model'; S stays
    whole per device (the visit schedule is global over S). The specs serve
    forward and both backward sweeps — dq shards like q, dk/dv like k/v."""
    axes = axes_for(part, lead, part.flash_axes)
    a = axes or None
    return P(a, None, None, None), P(a, None, None)


def paged_specs(part: KernelPartitioning, batch: int) -> tuple[P, P, P, P]:
    """(q, page_table, lengths, pool) specs for paged decode.

    The batch-slot axis shards q [B, KV, G, hd], the page table
    [B, max_pages], and lengths [B] *together* — each device looks up its
    own slots' rows — while the KV pool stays replicated so any page id
    resolves locally. (Replicating the table against a sharded B would
    index the wrong rows; replicating the pool is what keeps the scalar-
    prefetched indices valid everywhere.)"""
    axes = axes_for(part, batch, part.paged_axes)
    b = axes or None
    return (P(b, None, None, None), P(b, None), P(b),
            P(None, None, None, None))


# ---------------------------------------------------------------------------
# Paged decode attention (the serving hot path)
# ---------------------------------------------------------------------------
#
# Serving keeps KV in a fixed pool of fixed-size pages
# (``src/repro/serving/paging.py``); a sequence owns an ordered page list and
# the decode step attends one q token against its own pages only. The page
# table plays exactly the role the visit schedule plays in training: it is a
# host-built int32 array, carried in via scalar prefetch, whose entries the
# index maps read to decide which KV tile each grid step loads — pages are
# the visit schedule one level up. Page 0 is the reserved *null page*
# (garbage scratch): table rows are 0-padded past a sequence's allocation,
# and every slot the mask rules out contributes exactly zero (the same
# explicit p-masking trick that makes block skipping bitwise inert).


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, ps, G, hd, window, scale, npages):
    b, j = pl.program_id(0), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # [G, hd]
    k = k_ref[0, 0].astype(jnp.float32)  # [ps, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # positions stored in page j of this sequence; the current token (at
    # position length-1) is already written, so valid = pos < length, plus
    # the sliding window lower bound when set
    pos = j * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
    length = len_ref[b]
    mask = pos < length
    if window:
        mask &= pos > length - 1 - window
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)
    acc_new = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(j == npages - 1)
    def _epilogue():
        o_ref[0, 0] = (acc_new / jnp.maximum(l_new, 1e-30)).astype(o_ref.dtype)


def _paged_decode_pallas(q, k_pages, v_pages, page_table, lengths, *,
                         window, interpret):
    B, KV, G, hd = q.shape
    ps = k_pages.shape[1]
    npages = page_table.shape[1]
    scale = 1.0 / math.sqrt(hd)
    # kernel layout: pages travel [n_pages, KV, ps, hd] so the (page, head)
    # tile is contiguous per grid step
    kp = k_pages.transpose(0, 2, 1, 3)
    vp = v_pages.transpose(0, 2, 1, 3)
    q_spec = pl.BlockSpec((1, 1, G, hd), lambda b, h, j, tbl, lens: (b, h, 0, 0))
    kv_spec = pl.BlockSpec((1, 1, ps, hd),
                           lambda b, h, j, tbl, lens: (tbl[b, j], h, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, npages),
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=[q_spec],
        scratch_shapes=[pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, hd), jnp.float32)])
    kernel = functools.partial(_paged_kernel, ps=ps, G=G, hd=hd,
                               window=window, scale=scale, npages=npages)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype)],
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32), q, kp, vp)
    return out[0]


def _paged_decode_xla(q, k_pages, v_pages, page_table, lengths, *, window):
    """Gather fallback: dense jnp ops only, so GSPMD plans still lower."""
    B, KV, G, hd = q.shape
    ps = k_pages.shape[1]
    npages = page_table.shape[1]
    # [B, npages, ps, KV, hd] -> [B, npages*ps, KV, hd]
    kg = k_pages[page_table].reshape(B, npages * ps, KV, hd)
    vg = v_pages[page_table].reshape(B, npages * ps, KV, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", q.astype(jnp.float32),
                   kg.astype(jnp.float32)) / math.sqrt(hd)
    pos = jnp.arange(npages * ps)[None, :]
    mask = pos < lengths[:, None]
    if window:
        mask &= pos > (lengths[:, None] - 1 - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgs,bskh->bkgh", p, vg)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                           page_table: jax.Array, lengths: jax.Array, *,
                           window: int = 0, impl: str = "xla",
                           interpret: bool | None = None) -> jax.Array:
    """One-token GQA attention against a paged KV cache.

    q ``[B, H, hd]`` (the new token per sequence slot, RoPE applied);
    k/v pages ``[n_pool_pages, page_size, KV, hd]``; ``page_table``
    ``[B, max_pages]`` int32 page ids per slot (0 = the reserved null page,
    padding past the allocation); ``lengths`` ``[B]`` int32 sequence lengths
    *including* the current token (already written to its page).
    Returns ``[B, H, hd]``.

    ``impl='pallas'`` grids over (B, KV, max_pages) with the page table as
    scalar prefetch — each grid step DMAs exactly one owned page;
    ``impl='xla'`` is the dense-gather fallback that lowers under GSPMD.
    """
    B, H, hd = q.shape
    KV = k_pages.shape[2]
    assert H % KV == 0, (H, KV)
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    if impl == "pallas":
        if interpret is None:
            interpret = _interpret()
        local = functools.partial(_paged_decode_pallas, window=window,
                                  interpret=interpret)
        part = active_partitioning()
        if part is not None:
            q_spec, tbl_spec, len_spec, pool_spec = paged_specs(part, B)
            local = shard_wrap(
                local, part,
                in_specs=(q_spec, pool_spec, pool_spec, tbl_spec, len_spec),
                out_specs=q_spec)
        o = local(qg, k_pages, v_pages, page_table, lengths)
    else:
        o = _paged_decode_xla(qg, k_pages, v_pages, page_table, lengths,
                              window=window)
    return o.reshape(B, H, hd)


# ---------------------------------------------------------------------------
# Public API (model-layer layout)
# ---------------------------------------------------------------------------


def gqa_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_kv: int = DEFAULT_BLOCK_KV,
                        interpret: bool | None = None,
                        skip_blocks: bool = True) -> jax.Array:
    """Fused GQA flash attention.

    q ``[B, S, H, hd]``, k/v ``[B, S, KV, hd]`` -> ``[B, S, H, hd]``.
    Rows attend by absolute sequence position (the training layout, where
    ``positions == arange(S)``); ``window`` is the sliding-window width
    (0 = none) and only applies with ``causal=True`` in the model layer.
    Block sizes are clamped to divide S; ``skip_blocks=False`` runs the
    full (unskipped) grid — the oracle of the block-skip tests.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    assert H % KV == 0, (H, KV)
    G = H // KV
    bq = clamp_block(block_q, S)
    bkv = clamp_block(block_kv, S)
    if interpret is None:
        interpret = _interpret()
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, S, KV, G, hd).transpose(0, 2, 1, 3, 4).reshape(B * KV, S, G, hd)
    kg = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    fn = _flash_fn(bool(causal), int(window), bq, bkv, scale, bool(interpret),
                   bool(skip_blocks))
    part = active_partitioning()
    if part is not None:
        # shard_map OUTSIDE the custom_vjp: jax differentiates through the
        # mapped region, so the dq/dk/dv sweeps run under the same specs as
        # the forward (batch-local -> bitwise vs the single-device call)
        q_spec, kv_spec = flash_specs(part, B * KV)
        fn = shard_wrap(fn, part, in_specs=(q_spec, kv_spec, kv_spec),
                        out_specs=q_spec)
    o = fn(qg, kg, vg)
    return o.reshape(B, KV, S, G, hd).transpose(0, 2, 1, 3, 4).reshape(B, S, H, hd)
