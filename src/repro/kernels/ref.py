"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.muon import NS_COEFFS


def matmul_epilogue_ref(a, b, d=None, *, alpha=1.0, beta=0.0, out_dtype=None):
    out = alpha * (a.astype(jnp.float32) @ b.astype(jnp.float32))
    if d is not None and beta != 0.0:
        out = out + beta * d.astype(jnp.float32)
    return out.astype(out_dtype or a.dtype)


def ns_iteration_ref(x: jax.Array) -> jax.Array:
    """One quintic Newton-Schulz iteration on a single [m, n] matrix."""
    a, b, c = NS_COEFFS
    x32 = x.astype(jnp.float32)
    A = x32 @ x32.T
    B = b * A + c * (A @ A)
    return (a * x32 + B @ x32).astype(x.dtype)


def ns_orthogonalize_ref(g: jax.Array, iters: int = 5, eps: float = 1e-7) -> jax.Array:
    """Full NS orthogonalization oracle (fp32 throughout)."""
    orig = g.dtype
    m, n = g.shape[-2:]
    x = g.astype(jnp.float32)
    transpose = m > n
    if transpose:
        x = jnp.swapaxes(x, -1, -2)
    x = x / (jnp.sqrt(jnp.sum(x * x, axis=(-2, -1), keepdims=True)) + eps)
    for _ in range(iters):
        if x.ndim == 2:
            x = ns_iteration_ref(x)
        else:
            x = jax.vmap(ns_iteration_ref)(x)
    if transpose:
        x = jnp.swapaxes(x, -1, -2)
    return x.astype(orig)


def rowwise_quantize_ref(x: jax.Array, bits: int):
    x32 = x.astype(jnp.float32)
    lo = jnp.min(x32, axis=1, keepdims=True)
    hi = jnp.max(x32, axis=1, keepdims=True)
    nlevels = (1 << bits) - 1
    scale = (hi - lo) / nlevels
    scale = jnp.where(scale <= 0.0, 1.0, scale)
    q = jnp.round((x32 - lo) / scale)
    return (lo + q * scale).astype(x.dtype), q.astype(jnp.uint8), lo, scale


def rowwise_dequantize_ref(codes: jax.Array, lo: jax.Array, scale: jax.Array) -> jax.Array:
    """Receiver-side reconstruction oracle: lo + codes * scale (fp32)."""
    return lo + codes.astype(jnp.float32) * scale


def gqa_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0) -> jax.Array:
    """Dense fp32 GQA attention oracle for the flash kernel.

    q [B,S,H,hd], k/v [B,S,KV,hd] -> [B,S,H,hd]; rows attend by absolute
    position (training layout), ``window`` = sliding-window width (0=none).
    """
    NEG_INF = -2.0e38
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    i = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= i[:, None] >= i[None, :]
    if window:
        mask &= i[:, None] - i[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)


def paged_attention_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        page_table: jax.Array, lengths: jax.Array, *,
                        window: int = 0) -> jax.Array:
    """Dense fp32 oracle for the paged decode kernel.

    q [B,H,hd] (one new token per slot); k/v pages [P, ps, KV, hd];
    page_table [B, max_pages] int32; lengths [B] int32 include the current
    token. Gathers each slot's pages into a contiguous [len, KV, hd] view
    and runs plain masked GQA attention per slot.
    """
    NEG_INF = -2.0e38
    B, H, hd = q.shape
    ps = k_pages.shape[1]
    KV = k_pages.shape[2]
    G = H // KV
    npages = page_table.shape[1]
    outs = []
    for b in range(B):
        kg = k_pages[page_table[b]].reshape(npages * ps, KV, hd).astype(jnp.float32)
        vg = v_pages[page_table[b]].reshape(npages * ps, KV, hd).astype(jnp.float32)
        qb = q[b].reshape(KV, G, hd).astype(jnp.float32)
        s = jnp.einsum("kgh,skh->kgs", qb, kg) / jnp.sqrt(jnp.float32(hd))
        pos = jnp.arange(npages * ps)
        mask = pos < lengths[b]
        if window:
            mask &= pos > lengths[b] - 1 - window
        s = jnp.where(mask[None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        outs.append(jnp.einsum("kgs,skh->kgh", p, vg).reshape(H, hd))
    return jnp.stack(outs).astype(q.dtype)


def nesterov_update_ref(theta, psi, u, *, lr, momentum):
    psi32 = psi.astype(jnp.float32)
    u_new = momentum * u + lr * psi32
    theta_new = theta.astype(jnp.float32) - momentum * u_new - lr * psi32
    return theta_new.astype(theta.dtype), u_new
