"""Jit'd public wrappers around the Pallas kernels: padding to block
multiples, batching, and backend selection (interpret=True off-TPU)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.matmul import matmul_epilogue
from repro.kernels.outer_update import fused_nesterov_update
from repro.kernels.quantize import rowwise_dequantize, rowwise_quantize
from repro.kernels.topk_pack import pack_topk, unpack_topk  # noqa: F401 (re-export)
from repro.optim.muon import NS_COEFFS


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = []
    for dim, mult in zip(x.shape, mults):
        rem = (-dim) % mult
        pads.append((0, rem))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


@partial(jax.jit, static_argnames=("alpha", "beta", "block"))
def matmul(a: jax.Array, b: jax.Array, d: jax.Array | None = None, *,
           alpha: float = 1.0, beta: float = 0.0, block: int = 128) -> jax.Array:
    """C = alpha * a@b + beta * d with automatic padding."""
    m, k = a.shape
    _, n = b.shape
    ap = _pad_to(a, (block, block))
    bp = _pad_to(b, (block, block))
    dp = _pad_to(d, (block, block)) if d is not None else None
    out = matmul_epilogue(ap, bp, dp, alpha=alpha, beta=beta,
                          block_m=block, block_n=block, block_k=block,
                          interpret=_interpret())
    return out[:m, :n]


def _ns_iteration_pallas(x: jax.Array, block: int) -> jax.Array:
    a, b, c = NS_COEFFS
    A = matmul(x, x.T, block=block)                       # X X^T
    B = matmul(A, A, d=A, alpha=c, beta=b, block=block)   # c*A@A + b*A (fused epilogue)
    return matmul(B, x, d=x, alpha=1.0, beta=a, block=block)  # B@X + a*X (fused epilogue)


@partial(jax.jit, static_argnames=("iters", "block"))
def ns_orthogonalize(g: jax.Array, iters: int = 5, eps: float = 1e-7, block: int = 128) -> jax.Array:
    """Newton–Schulz orthogonalization of the trailing 2 dims via the Pallas
    matmul-epilogue kernel. Batched leading dims are vmapped."""
    orig_dtype = g.dtype
    *batch, m, n = g.shape
    x = g.reshape((-1, m, n)).astype(jnp.float32)
    transpose = m > n
    if transpose:
        x = jnp.swapaxes(x, -1, -2)
    x = x / (jnp.sqrt(jnp.sum(x * x, axis=(-2, -1), keepdims=True)) + eps)

    def one(xi):
        for _ in range(iters):
            xi = _ns_iteration_pallas(xi, block)
        return xi

    x = jax.vmap(one)(x) if x.shape[0] > 1 else one(x[0])[None]
    if transpose:
        x = jnp.swapaxes(x, -1, -2)
    return x.reshape((*batch, m, n)).astype(orig_dtype)


@partial(jax.jit, static_argnames=("bits", "block_rows"))
def quantize_rowwise(x: jax.Array, bits: int = 4, block_rows: int = 8):
    """Fused row-wise linear quant->dequant. Returns (dequantized, codes, lo, scale)."""
    m, n = x.shape
    xp = _pad_to(x, (block_rows, 1))
    deq, codes, lo, scale = rowwise_quantize(xp, bits, block_rows=block_rows,
                                             interpret=_interpret())
    return deq[:m], codes[:m], lo[:m], scale[:m]


@partial(jax.jit, static_argnames=("block_rows",))
def dequantize_rowwise(codes: jax.Array, lo: jax.Array, scale: jax.Array,
                       block_rows: int = 8) -> jax.Array:
    """Fused receiver-side reconstruction: (codes u8 [m, n], lo, scale) -> f32."""
    m, n = codes.shape
    cp = _pad_to(codes, (block_rows, 1))
    lp = _pad_to(lo, (block_rows, 1))
    sp = _pad_to(scale, (block_rows, 1))
    out = rowwise_dequantize(cp, lp, sp, block_rows=block_rows,
                             interpret=_interpret())
    return out[:m]


@partial(jax.jit, static_argnames=("lr", "momentum", "block"))
def nesterov_update(theta: jax.Array, psi: jax.Array, u: jax.Array, *,
                    lr: float, momentum: float, block: int = 1024):
    """Fused outer Nesterov update on arbitrary-shaped tensors."""
    shape = theta.shape
    t = _pad_to(theta.reshape(-1), (block,))
    p = _pad_to(psi.reshape(-1).astype(jnp.float32), (block,))
    uu = _pad_to(u.reshape(-1).astype(jnp.float32), (block,))
    n = theta.size
    t2, u2 = fused_nesterov_update(t, p, uu, lr=lr, momentum=momentum,
                                   block=block, interpret=_interpret())
    return t2[:n].reshape(shape), u2[:n].reshape(shape)
