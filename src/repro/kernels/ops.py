"""Public wrappers around the Pallas kernels: padding to block multiples,
batching, backend selection (interpret=True off-TPU), and mesh routing.

Each wrapper consults the kernel-partitioning context
(:mod:`repro.kernels.partition`) *outside* any jit cache: with no mesh
routed (the CPU/test default) it dispatches to the same jitted single-device
implementation as before; with a mesh routed by the StepPlan machinery it
shard_maps the kernel body over the specs the kernel module declares
(``rowwise_specs`` / ``ns_stack_spec`` / ``outer_update_spec``). Pad-to-block
happens inside the mapped region on local shapes, so sharding never changes
any element's arithmetic — the shard_mapped results are bitwise-identical
to the single-device calls (tests/test_shard_map.py).

The context read cannot live inside ``@jax.jit``: a cached trace would pin
whichever routing was active at first call. The public functions are plain
Python that pick the jitted or shard_mapped path per call; inside an outer
jit (every production call site) both paths are inlined into the enclosing
trace anyway.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.matmul import matmul_epilogue, ns_stack_spec
from repro.kernels.outer_update import fused_nesterov_update, outer_update_spec
from repro.kernels.partition import active_partitioning, shard_wrap
from repro.kernels.quantize import rowwise_dequantize, rowwise_quantize, rowwise_specs
from repro.kernels.topk_pack import pack_topk, unpack_topk  # noqa: F401 (re-export)
from repro.optim.muon import NS_COEFFS


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = []
    for dim, mult in zip(x.shape, mults):
        rem = (-dim) % mult
        pads.append((0, rem))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


@partial(jax.jit, static_argnames=("alpha", "beta", "block"))
def matmul(a: jax.Array, b: jax.Array, d: jax.Array | None = None, *,
           alpha: float = 1.0, beta: float = 0.0, block: int = 128) -> jax.Array:
    """C = alpha * a@b + beta * d with automatic padding.

    Whole-matrix (device-local) by construction: on a mesh this runs inside
    the shard_mapped NS stack, never partitioned on its own."""
    m, k = a.shape
    _, n = b.shape
    ap = _pad_to(a, (block, block))
    bp = _pad_to(b, (block, block))
    dp = _pad_to(d, (block, block)) if d is not None else None
    out = matmul_epilogue(ap, bp, dp, alpha=alpha, beta=beta,
                          block_m=block, block_n=block, block_k=block,
                          interpret=_interpret())
    return out[:m, :n]


def _ns_iteration_pallas(x: jax.Array, block: int) -> jax.Array:
    a, b, c = NS_COEFFS
    A = matmul(x, x.T, block=block)                       # X X^T
    B = matmul(A, A, d=A, alpha=c, beta=b, block=block)   # c*A@A + b*A (fused epilogue)
    return matmul(B, x, d=x, alpha=1.0, beta=a, block=block)  # B@X + a*X (fused epilogue)


def _ns_stack(g3: jax.Array, *, iters: int, eps: float, block: int) -> jax.Array:
    """[bsz, m, n] -> orthogonalized [bsz, m, n]; matrix-local, so safe to
    shard_map over the stack axis."""
    m, n = g3.shape[-2:]
    x = g3.astype(jnp.float32)
    transpose = m > n
    if transpose:
        x = jnp.swapaxes(x, -1, -2)
    x = x / (jnp.sqrt(jnp.sum(x * x, axis=(-2, -1), keepdims=True)) + eps)

    def one(xi):
        for _ in range(iters):
            xi = _ns_iteration_pallas(xi, block)
        return xi

    x = jax.vmap(one)(x) if x.shape[0] > 1 else one(x[0])[None]
    if transpose:
        x = jnp.swapaxes(x, -1, -2)
    return x.astype(g3.dtype)


@partial(jax.jit, static_argnames=("iters", "block"))
def _ns_orthogonalize_jit(g, iters, eps, block):
    orig_dtype = g.dtype
    *batch, m, n = g.shape
    out = _ns_stack(g.reshape((-1, m, n)), iters=iters, eps=eps, block=block)
    return out.reshape((*batch, m, n)).astype(orig_dtype)


def ns_orthogonalize(g: jax.Array, iters: int = 5, eps: float = 1e-7,
                     block: int | None = None) -> jax.Array:
    """Newton–Schulz orthogonalization of the trailing 2 dims via the Pallas
    matmul-epilogue kernel. Batched leading dims are folded into the matrix
    stack — vmapped on one device, shard_mapped over the stack axis when a
    mesh is routed (whole matrices always stay device-local).

    ``block=None`` (the default) consults the autotune table for this
    (m, n, dtype, backend) and falls back to the historical 128 on a miss;
    sweep entries are bitwise-gated, so a tuned block can only retile the
    NS matmuls without splitting the contraction."""
    if block is None:
        m, n = g.shape[-2:]
        block = autotune.ns_block(m, n, str(g.dtype)) or 128
    part = active_partitioning()
    if part is None:
        return _ns_orthogonalize_jit(g, iters, eps, block)
    *batch, m, n = g.shape
    g3 = g.reshape((-1, m, n))
    spec = ns_stack_spec(part, g3.shape[0])
    fn = shard_wrap(partial(_ns_stack, iters=iters, eps=eps, block=block),
                    part, in_specs=(spec,), out_specs=spec)
    return fn(g3).reshape(g.shape)


def _quantize_body(x: jax.Array, *, bits: int, block_rows: int):
    m, _ = x.shape
    xp = _pad_to(x, (block_rows, 1))
    deq, codes, lo, scale = rowwise_quantize(xp, bits, block_rows=block_rows,
                                             interpret=_interpret())
    return deq[:m], codes[:m], lo[:m], scale[:m]


@partial(jax.jit, static_argnames=("bits", "block_rows"))
def _quantize_rowwise_jit(x, bits, block_rows):
    return _quantize_body(x, bits=bits, block_rows=block_rows)


def quantize_rowwise(x: jax.Array, bits: int = 4, block_rows: int | None = None):
    """Fused row-wise linear quant->dequant. Returns (dequantized, codes, lo, scale).

    On a routed mesh the row axis is shard_mapped per ``rowwise_specs``
    (rows are independent — each carries its own lo/scale).

    ``block_rows=None`` consults the autotune table for this wire shape and
    falls back to the historical 8 on a miss. block_rows is pure row tiling
    (every row quantizes against its own lo/scale), so any tuned value is
    bitwise-inert — the sweep's gate re-verifies that per shape anyway."""
    if block_rows is None:
        block_rows = autotune.quantize_block_rows(
            x.shape[0], x.shape[1], bits, str(x.dtype)) or 8
    part = active_partitioning()
    if part is None:
        return _quantize_rowwise_jit(x, bits, block_rows)
    mat, meta = rowwise_specs(part, x.shape[0])
    fn = shard_wrap(partial(_quantize_body, bits=bits, block_rows=block_rows),
                    part, in_specs=(mat,), out_specs=(mat, mat, meta, meta))
    return fn(x)


def _dequantize_body(codes: jax.Array, lo: jax.Array, scale: jax.Array, *,
                     block_rows: int) -> jax.Array:
    m, _ = codes.shape
    cp = _pad_to(codes, (block_rows, 1))
    lp = _pad_to(lo, (block_rows, 1))
    sp = _pad_to(scale, (block_rows, 1))
    out = rowwise_dequantize(cp, lp, sp, block_rows=block_rows,
                             interpret=_interpret())
    return out[:m]


@partial(jax.jit, static_argnames=("block_rows",))
def _dequantize_rowwise_jit(codes, lo, scale, block_rows):
    return _dequantize_body(codes, lo, scale, block_rows=block_rows)


def dequantize_rowwise(codes: jax.Array, lo: jax.Array, scale: jax.Array,
                       block_rows: int | None = None) -> jax.Array:
    """Fused receiver-side reconstruction: (codes u8 [m, n], lo, scale) -> f32.

    ``block_rows=None`` resolves through the autotune table under the SAME
    key the quantizer uses (the wire shape + bits=4 wire default), so both
    ends of the wire pick the same tiling."""
    if block_rows is None:
        block_rows = autotune.quantize_block_rows(
            codes.shape[0], codes.shape[1], 4, "float32") or 8
    part = active_partitioning()
    if part is None:
        return _dequantize_rowwise_jit(codes, lo, scale, block_rows)
    mat, meta = rowwise_specs(part, codes.shape[0])
    fn = shard_wrap(partial(_dequantize_body, block_rows=block_rows),
                    part, in_specs=(mat, meta, meta), out_specs=mat)
    return fn(codes, lo, scale)


def _nesterov_flat(t: jax.Array, p: jax.Array, uu: jax.Array, *,
                   lr: float, momentum: float, block: int):
    n = t.shape[0]
    t2, u2 = fused_nesterov_update(
        _pad_to(t, (block,)), _pad_to(p, (block,)), _pad_to(uu, (block,)),
        lr=lr, momentum=momentum, block=block, interpret=_interpret())
    return t2[:n], u2[:n]


@partial(jax.jit, static_argnames=("lr", "momentum", "block"))
def _nesterov_update_jit(theta, psi, u, lr, momentum, block):
    shape = theta.shape
    t2, u2 = _nesterov_flat(
        theta.reshape(-1), psi.reshape(-1).astype(jnp.float32),
        u.reshape(-1).astype(jnp.float32), lr=lr, momentum=momentum, block=block)
    return t2.reshape(shape), u2.reshape(shape)


def _nesterov_block(t: jax.Array, p: jax.Array, uu: jax.Array, *,
                    lr: float, momentum: float, block: int):
    """Shape-preserving mapped body: flatten the *local* block, run the
    elementwise kernel, restore the local shape."""
    shape = t.shape
    t2, u2 = _nesterov_flat(t.reshape(-1), p.reshape(-1), uu.reshape(-1),
                            lr=lr, momentum=momentum, block=block)
    return t2.reshape(shape), u2.reshape(shape)


def nesterov_update(theta: jax.Array, psi: jax.Array, u: jax.Array, *,
                    lr: float, momentum: float, block: int = 1024):
    """Fused outer Nesterov update on arbitrary-shaped tensors.

    On a routed mesh the operands are shard_mapped in the outer state's own
    ZeRO layout (``outer_update_spec`` — shape-preserving, flatten happens
    per shard), which keeps the donated TrainState aliased through the
    round/superstep programs; the update is elementwise, so every split is
    bitwise-exact."""
    part = active_partitioning()
    if part is None:
        return _nesterov_update_jit(theta, psi, u, lr, momentum, block)
    spec = outer_update_spec(part, theta.shape)
    fn = shard_wrap(partial(_nesterov_block, lr=lr, momentum=momentum, block=block),
                    part, in_specs=(spec, spec, spec), out_specs=(spec, spec))
    return fn(theta, psi.astype(jnp.float32), u.astype(jnp.float32))
