"""Row-wise linear quantize→dequantize Pallas kernels + code bit-packing.

The paper argues row-wise quantization is the production choice because each
row carries its own (min, scale) metadata and the dequantize-reduce-quantize
in the all-to-all reduce-scatter parallelizes per row (§6.3 "Global v.s.
Row-wise"). The encode kernel fuses: per-row min/max reduction, code
assignment, and dequantization in one VMEM pass over a [block_rows, n] tile.
Codes are emitted alongside the dequantized values so the wire format
(bit-packed uint8 codes + fp32 row metadata) is materialized for the
collective layer; :func:`rowwise_dequantize` is the receiver side (codes +
metadata -> values, the reconstruction both the reduce and the EF residual
see). :func:`pack_codes` / :func:`unpack_codes` implement the on-the-wire
byte layout: for bits in {1, 2, 4, 8}, 8/bits codes share one byte.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.sharding import PartitionSpec as P

from repro.kernels.partition import KernelPartitioning, axes_for


def rowwise_specs(part: KernelPartitioning, rows: int) -> tuple[P, P]:
    """(matrix_spec [rows, n], meta_spec [rows, 1]) for the shard_mapped
    encode/decode: rows are independent (each carries its own lo/scale), so
    the row axis shards over the preference — worker-stacked leaves fold K
    into rows before the kernel, hence ('pod', 'data'). Columns stay whole
    (the per-row min/max reduction spans them). Padding to block_rows
    multiples happens inside the mapped region, so per-row arithmetic is
    unchanged by the split."""
    axes = axes_for(part, rows, part.quantize_axes)
    r = axes or None
    return P(r, None), P(r, None)


def _rowwise_quant_kernel(x_ref, deq_ref, code_ref, lo_ref, scale_ref, *, bits):
    x = x_ref[...].astype(jnp.float32)  # [bm, n]
    lo = jnp.min(x, axis=1, keepdims=True)
    hi = jnp.max(x, axis=1, keepdims=True)
    nlevels = (1 << bits) - 1
    scale = (hi - lo) / nlevels
    scale = jnp.where(scale <= 0.0, 1.0, scale)
    q = jnp.round((x - lo) / scale)
    code_ref[...] = q.astype(jnp.uint8)
    deq_ref[...] = (lo + q * scale).astype(deq_ref.dtype)
    lo_ref[...] = lo
    scale_ref[...] = scale


def rowwise_quantize(
    x: jax.Array,
    bits: int = 4,
    *,
    block_rows: int = 8,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """x: [m, n] (m % block_rows == 0) -> (dequantized, codes u8, lo, scale)."""
    assert bits <= 8, "codes are u8 on the wire"
    m, n = x.shape
    assert m % block_rows == 0, f"pad rows to a multiple of {block_rows}"
    kernel = functools.partial(_rowwise_quant_kernel, bits=bits)
    deq, codes, lo, scale = pl.pallas_call(
        kernel,
        grid=(m // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), x.dtype),
            jax.ShapeDtypeStruct((m, n), jnp.uint8),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return deq, codes, lo, scale


def _rowwise_dequant_kernel(code_ref, lo_ref, scale_ref, out_ref):
    q = code_ref[...].astype(jnp.float32)  # [bm, n]
    out_ref[...] = (lo_ref[...] + q * scale_ref[...]).astype(out_ref.dtype)


def rowwise_dequantize(
    codes: jax.Array,
    lo: jax.Array,
    scale: jax.Array,
    *,
    block_rows: int = 8,
    interpret: bool = True,
    out_dtype=jnp.float32,
) -> jax.Array:
    """The receiver side: (codes u8 [m, n], lo [m, 1], scale [m, 1]) -> values.

    One VMEM pass per [block_rows, n] tile; bit-identical to the jnp
    reconstruction ``lo + codes * scale`` (same ops, same order)."""
    m, n = codes.shape
    assert m % block_rows == 0, f"pad rows to a multiple of {block_rows}"
    (out,) = pl.pallas_call(
        _rowwise_dequant_kernel,
        grid=(m // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((m, n), out_dtype)],
        interpret=interpret,
    )(codes, lo, scale)
    return out


# ---------------------------------------------------------------------------
# Wire byte layout: bit-packing of quantization codes
# ---------------------------------------------------------------------------


def packed_width(n: int, bits: int) -> int:
    """Bytes per row of n codes at the given width (ceil; 1 byte/code when
    bits does not divide 8)."""
    if 8 % bits:
        return n
    per = 8 // bits
    return (n + per - 1) // per


def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """[..., n] u8 codes -> [..., packed_width(n, bits)] u8 wire bytes.

    For bits in {1, 2, 4, 8} exactly 8/bits codes share one byte (code i of a
    group occupies bits [i*bits, (i+1)*bits)); other widths ship one code per
    byte. Lossless: :func:`unpack_codes` inverts it exactly.
    """
    if 8 % bits:
        return codes
    per = 8 // bits
    n = codes.shape[-1]
    pad = (-n) % per
    if pad:
        codes = jnp.pad(codes, [(0, 0)] * (codes.ndim - 1) + [(0, pad)])
    grouped = codes.reshape(*codes.shape[:-1], -1, per)
    packed = jnp.zeros(grouped.shape[:-1], jnp.uint8)
    for i in range(per):
        packed = packed | (grouped[..., i] << jnp.uint8(i * bits))
    return packed


def unpack_codes(packed: jax.Array, bits: int, n: int) -> jax.Array:
    """Inverse of :func:`pack_codes`: [..., packed] u8 -> [..., n] u8 codes."""
    if 8 % bits:
        return packed[..., :n]
    per = 8 // bits
    mask = jnp.uint8((1 << bits) - 1)
    parts = [(packed >> jnp.uint8(i * bits)) & mask for i in range(per)]
    codes = jnp.stack(parts, axis=-1).reshape(*packed.shape[:-1], -1)
    return codes[..., :n]
