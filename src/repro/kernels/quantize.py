"""Row-wise linear quantize→dequantize Pallas kernel.

The paper argues row-wise quantization is the production choice because each
row carries its own (min, scale) metadata and the dequantize-reduce-quantize
in the all-to-all reduce-scatter parallelizes per row (§6.3 "Global v.s.
Row-wise"). The kernel fuses: per-row min/max reduction, code assignment, and
dequantization in one VMEM pass over a [block_rows, n] tile. Codes are
emitted alongside the dequantized values so the wire format (uint8 codes +
fp32 row metadata) is materialized for the collective layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rowwise_quant_kernel(x_ref, deq_ref, code_ref, lo_ref, scale_ref, *, bits):
    x = x_ref[...].astype(jnp.float32)  # [bm, n]
    lo = jnp.min(x, axis=1, keepdims=True)
    hi = jnp.max(x, axis=1, keepdims=True)
    nlevels = (1 << bits) - 1
    scale = (hi - lo) / nlevels
    scale = jnp.where(scale <= 0.0, 1.0, scale)
    q = jnp.round((x - lo) / scale)
    code_ref[...] = q.astype(jnp.uint8)
    deq_ref[...] = (lo + q * scale).astype(deq_ref.dtype)
    lo_ref[...] = lo
    scale_ref[...] = scale


def rowwise_quantize(
    x: jax.Array,
    bits: int = 4,
    *,
    block_rows: int = 8,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """x: [m, n] (m % block_rows == 0) -> (dequantized, codes u8, lo, scale)."""
    assert bits <= 8, "codes are u8 on the wire"
    m, n = x.shape
    assert m % block_rows == 0, f"pad rows to a multiple of {block_rows}"
    kernel = functools.partial(_rowwise_quant_kernel, bits=bits)
    deq, codes, lo, scale = pl.pallas_call(
        kernel,
        grid=(m // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), x.dtype),
            jax.ShapeDtypeStruct((m, n), jnp.uint8),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return deq, codes, lo, scale
