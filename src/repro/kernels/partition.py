"""Kernel partitioning: shard_map routing for every Pallas call site.

Pallas calls carry no GSPMD partitioning rules, so a bare ``pl.pallas_call``
inside a jit that spans a multi-device mesh fails to lower — which is why
every fused kernel used to fall back to XLA on the production mesh. The fix
is the maxtext-DiLoCo combination: wrap the kernel call in
``jax.experimental.shard_map`` with explicit PartitionSpecs, so GSPMD sees
an opaque per-device region and each device runs the kernel on its local
block. All five kernels are embarrassingly parallel over the axes we shard
(batch*kv-head rows for flash attention, quantize rows, stacked
Newton-Schulz matrices, elementwise outer updates in the state's own
layout, serving batch slots), so the shard_mapped result is bitwise-identical to the
single-device call — padding to block multiples happens *inside* the mapped
region, on local shapes, so splitting an axis never changes any row's
arithmetic.

The routing lives in a ContextVar installed by the StepPlan machinery
(:func:`repro.launch.sharding.kernel_specs` builds the
:class:`KernelPartitioning`, ``launch/steps.py`` installs it around every
step fn), mirroring the ``activation_sharding`` pattern in
``models/common.py``: the kernel wrappers in ``kernels/ops.py`` /
``kernels/flash_attention.py`` consult :func:`active_partitioning` at trace
time and shard_map themselves when a mesh is routed. With no context
installed the kernels behave exactly as before (single-device pallas_call),
so the CPU test path is unchanged.

Axis preferences degrade gracefully: :func:`axes_for` takes the longest
*prefix* of the preferred mesh axes whose product divides the dim being
sharded, falling back to full replication (which always lowers) when
nothing divides. Scalar-prefetch operands that must stay whole — the flash
visit schedule (a closed-over trace constant) and the paged-KV pool — are
replicated; the page *table* is co-sharded with its batch-slot axis so each
device indexes its own slots against the replicated pool.
"""
from __future__ import annotations

import dataclasses
from contextvars import ContextVar
from typing import Any, Callable

from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class KernelPartitioning:
    """Mesh + per-kernel axis preferences for shard_mapping Pallas calls.

    Each ``*_axes`` tuple is an ordered mesh-axis preference for the axis
    that kernel shards (see ``docs/architecture.md`` "Kernels on the mesh"):

    * ``flash_axes``   — the fused [B*KV, S, G, hd] batch-head axis. B-major
      ordering means ('data', 'model') aligns with batch->data, kv->model.
      The worker axis K is NOT listed: ``inner_step`` vmaps with
      ``spmd_axis_name='pod'``, and shard_map's batching rule inserts 'pod'
      into the specs at the vmapped dim.
    * ``quantize_axes`` — wire-quantize rows ([K-folded rows, n]; K folds
      into the row axis before the kernel, hence 'pod' leads).
    * ``ns_axes``      — the stacked-matrix batch axis of Newton-Schulz
      ([L*heads..., m, n]); whole matrices stay local (replicated-or-rowwise
      per label — stacks that don't divide run replicated).
    * ``paged_axes``   — the serving batch-slot axis of paged decode (the
      page table rides along; the KV pool is replicated).

    The fused outer update has no axis preference here: its specs are
    shape-preserving and mirror the outer-state ZeRO layout directly
    (:func:`repro.kernels.outer_update.outer_update_spec`); ``outer_tp``
    records whether that layout shards dim -1 over 'model' (the
    tensor-parallel-friendliness of the arch, decided by ``kernel_specs``).
    """

    mesh: Mesh
    flash_axes: tuple[str, ...] = ("data", "model")
    quantize_axes: tuple[str, ...] = ("pod", "data")
    ns_axes: tuple[str, ...] = ("data",)
    paged_axes: tuple[str, ...] = ("data",)
    outer_tp: bool = True

    def axis_sizes(self) -> dict[str, int]:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))


_KERNEL_PARTS: ContextVar[KernelPartitioning | None] = ContextVar(
    "kernel_parts", default=None)


class kernel_partitioning:
    """Context manager routing kernel calls through shard_map.

    ``parts=None`` is a no-op (so call sites can install unconditionally)::

        with kernel_partitioning(kernel_specs(mesh, cfg)):
            loss = train_step(state, batch)   # pallas calls shard_map'd
    """

    def __init__(self, parts: KernelPartitioning | None):
        self.parts = parts
        self._toks: list = []  # stack: instances are re-entered every trace

    def __enter__(self):
        self._toks.append(_KERNEL_PARTS.set(self.parts))
        return self

    def __exit__(self, *exc):
        _KERNEL_PARTS.reset(self._toks.pop())
        return False


def active_partitioning() -> KernelPartitioning | None:
    """The installed routing, or None (single-device kernel behavior)."""
    return _KERNEL_PARTS.get()


def axes_for(part: KernelPartitioning, dim: int,
             prefer: tuple[str, ...]) -> tuple[str, ...]:
    """Longest prefix of ``prefer`` whose mesh-size product divides ``dim``.

    Prefix (not subset) semantics keep the major-to-minor alignment of the
    composite axis; an empty result means replicate (always lowers)."""
    sizes = part.axis_sizes()
    chosen: list[str] = []
    prod = 1
    for name in prefer:
        n = sizes.get(name, 1)
        if n <= 1:
            continue
        if dim % (prod * n):
            break
        chosen.append(name)
        prod *= n
    return tuple(chosen)


def shard_wrap(fn: Callable, part: KernelPartitioning,
               in_specs: Any, out_specs: Any) -> Callable:
    """shard_map ``fn`` on the routed mesh.

    ``check_rep=False``: the kernel bodies are opaque to shard_map's
    replication checker (pallas_call has no replication rule), and every
    wrapped kernel is batch-local — no cross-device reduction ever happens
    inside the mapped region."""
    return shard_map(fn, mesh=part.mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)
