"""Blocked matmul with fused polynomial epilogue — the Newton–Schulz hot-spot.

Muon's Newton–Schulz iteration is three chained matmuls per step:

    A = X X^T ;  B = b*A + c*(A A) ;  X' = a*X + B X

Each is an instance of ``C = alpha * (A @ B) + beta * D`` — so one Pallas
kernel with an axpy epilogue covers the whole iteration and keeps the
epilogue adds in VMEM (no extra HBM round-trips between the polynomial
terms, the TPU-native answer to the fused-CUDA Muon step).

Tiling: grid (m/bm, n/bn, k/bk); fp32 accumulator scratch in VMEM; MXU-
aligned 128x128x128 default blocks. Inputs are padded to block multiples by
the ops.py wrapper (zero padding is exact for matmul).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x ships TPUCompilerParams; newer releases renamed it CompilerParams
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def ns_stack_spec(part, bsz: int):
    """shard_map spec for a [bsz, m, n] Newton-Schulz matrix stack.

    Whole matrices stay device-local (the three chained matmuls of one NS
    iteration reduce over full rows/columns — exactly the layout the refuted
    'ns_matrix' GSPMD resharding hints tried and failed to get; shard_map
    makes it explicit instead). Only the stacked-matrix batch axis shards,
    and only when it divides — the common replicated fallback also lowers,
    which is what turns ``--ns-impl pallas`` legal on the production mesh.
    """
    from jax.sharding import PartitionSpec as P

    from repro.kernels.partition import axes_for

    axes = axes_for(part, bsz, part.ns_axes)
    return P(axes or None, None, None)


def _matmul_epilogue_kernel(a_ref, b_ref, d_ref, o_ref, acc_ref, *, alpha, beta, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        out = alpha * acc_ref[...]
        if beta != 0.0:
            out = out + beta * d_ref[...].astype(jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)


def matmul_epilogue(
    a: jax.Array,
    b: jax.Array,
    d: jax.Array | None = None,
    *,
    alpha: float = 1.0,
    beta: float = 0.0,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
    out_dtype=None,
) -> jax.Array:
    """C = alpha * (a @ b) + beta * d for 2-D operands (pre-padded shapes)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"shapes ({m},{k})x({k},{n}) must be multiples of blocks "
        f"({block_m},{block_n},{block_k}); pad in ops.py"
    )
    if d is None:
        d = jnp.zeros((m, n), a.dtype)
        beta = 0.0
    k_steps = k // block_k
    out_dtype = out_dtype or a.dtype

    kernel = functools.partial(
        _matmul_epilogue_kernel, alpha=alpha, beta=beta, k_steps=k_steps
    )
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, k_steps),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(a, b, d)
