"""Fused Nesterov outer update kernel (paper Eq. 3).

    u'     = mu * u + eta * psi
    theta' = theta - mu * u' - eta * psi

One elementwise VMEM pass producing both outputs — on TPU this halves the
HBM traffic of the outer step vs materializing u' then re-reading it, which
matters because the outer step touches 3 full parameter copies.

The kernel sits behind the ``nesterov`` outer transform
(:mod:`repro.optim.nesterov`): ``DiLoCoConfig.outer_kernel=True`` /
``--outer-kernel`` routes the terminal ``apply`` of the pseudogradient chain
through :func:`repro.kernels.ops.nesterov_update` instead of pure XLA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def outer_update_spec(part, shape: tuple[int, ...]):
    """Shape-preserving shard_map spec for one outer-update operand.

    Mirrors the outer-state ZeRO layout of
    :func:`repro.launch.sharding.param_spec` (``outer=True``) exactly:
    matrices shard dim -2 over ('pod','data') (falling back to 'data', then
    replicated, on non-divisible dims) and dim -1 over 'model' when the
    partitioning says the arch is TP-friendly; vectors/scalars replicate.
    Matching the committed sharding is what keeps the donated TrainState
    aliased through the round/superstep programs — a flat global reshape
    would force a reshard and lose the ``input_output_alias`` entries (the
    update is elementwise, so flattening happens per-shard inside the
    mapped region instead)."""
    from jax.sharding import PartitionSpec as P

    sizes = part.axis_sizes()
    nd = len(shape)
    if nd <= 1:
        return P(*([None] * nd))

    def div(dim: int, k: int) -> bool:
        return k > 0 and dim % k == 0 and dim >= k

    pod, data = sizes.get("pod", 0), sizes.get("data", 0)
    spec: list = [None] * nd
    if pod and div(shape[-2], pod * data):
        spec[-2] = ("pod", "data")
    elif div(shape[-2], data):
        spec[-2] = "data"
    if part.outer_tp and div(shape[-1], sizes.get("model", 0)):
        spec[-1] = "model"
    return P(*spec)


def _nesterov_kernel(theta_ref, psi_ref, u_ref, theta_out_ref, u_out_ref, *, lr, momentum):
    psi = psi_ref[...].astype(jnp.float32)
    u_new = momentum * u_ref[...] + lr * psi
    theta = theta_ref[...].astype(jnp.float32)
    theta_out_ref[...] = (theta - momentum * u_new - lr * psi).astype(theta_out_ref.dtype)
    u_out_ref[...] = u_new


def fused_nesterov_update(
    theta: jax.Array,
    psi: jax.Array,
    u: jax.Array,
    *,
    lr: float,
    momentum: float,
    block: int = 1024,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Flat [n] arrays (n % block == 0; ops.py pads) -> (theta', u')."""
    (n,) = theta.shape
    assert n % block == 0
    kernel = functools.partial(_nesterov_kernel, lr=lr, momentum=momentum)
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), theta.dtype),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(theta, psi, u)
